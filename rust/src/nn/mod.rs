//! CNN topology zoo: the networks the paper evaluates, as streamlined MVAU
//! graphs with exact tensor shapes (the quantity every OCM/throughput claim
//! depends on).
//!
//! * [`cnv`] — the BNN-Pynq CIFAR-10 topology (Zynq class, Tables I/IV/V);
//! * [`resnet50`] — quantized ResNet-50 v1.5, 16 resblocks (Alveo class,
//!   Tables II/IV/V, Figs 4/5).
//!
//! A [`Network`] is a list of [`Stage`]s; resblocks keep their branch/join
//! structure (needed by the pipeline simulator for bypass-FIFO sizing).

pub mod cnv;
pub mod mlp;
pub mod resnet;

pub use cnv::{cnv, CnvVariant};
pub use mlp::{lfc_w1a1, mlp, sfc_w1a1};
pub use resnet::{resnet50, resnet50_scaled};

/// Quantized-layer kind, for resource modelling.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LayerKind {
    Conv,
    FullyConnected,
}

/// One streamlined MVAU layer (conv or FC) with folding and geometry.
#[derive(Clone, Debug)]
pub struct Layer {
    pub name: String,
    pub kind: LayerKind,
    /// Convolution kernel size K (1 for FC / pointwise).
    pub k: u64,
    pub c_in: u64,
    pub c_out: u64,
    pub stride: u64,
    pub pad: u64,
    /// Input feature-map height/width (square maps, as in both topologies).
    pub ifm: u64,
    /// Weight precision in bits (1 binary, 2 ternary, 8 int8).
    pub wbits: u64,
    /// Output activation precision in bits (0 = none / raw accumulator).
    pub abits: u64,
    /// Neuron (output channel) parallelism.
    pub pe: u64,
    /// Synapse (input) parallelism.
    pub simd: u64,
    /// Excluded from OCM packing (paper §V: first layer small, last layer
    /// in URAM/HBM/DDR).
    pub exclude_from_packing: bool,
}

impl Layer {
    /// Output feature-map dimension.
    pub fn ofm(&self) -> u64 {
        (self.ifm + 2 * self.pad - self.k) / self.stride + 1
    }

    /// Rows of the weight matrix: synapses per neuron.
    pub fn synapses(&self) -> u64 {
        self.k * self.k * self.c_in
    }

    /// Total weight parameters.
    pub fn params(&self) -> u64 {
        self.synapses() * self.c_out
    }

    /// Total weight bits.
    pub fn weight_bits(&self) -> u64 {
        self.params() * self.wbits
    }

    /// Weight buffer width in bits as read per compute cycle (PE·SIMD·W).
    pub fn buffer_width_bits(&self) -> u64 {
        self.pe * self.simd * self.wbits
    }

    /// Weight buffer depth in words (total folds).
    pub fn buffer_depth(&self) -> u64 {
        debug_assert_eq!(self.synapses() % self.simd, 0, "{}: SIMD|S", self.name);
        debug_assert_eq!(self.c_out % self.pe, 0, "{}: PE|C", self.name);
        (self.synapses() / self.simd) * (self.c_out / self.pe)
    }

    /// Compute cycles per frame: folds × output pixels (the FINN II model).
    pub fn cycles_per_frame(&self) -> u64 {
        self.buffer_depth() * self.ofm() * self.ofm()
    }

    /// Multiply-accumulate ops per frame (for TOp/s accounting; ×2 for MAC).
    pub fn ops_per_frame(&self) -> u64 {
        2 * self.params() * self.ofm() * self.ofm()
    }

    /// Halve the parallelism (the paper's "additional folding" alternative,
    /// e.g. RN50-W1A2-U280-F2). Prefers halving PE, then SIMD.
    pub fn fold2(&self) -> Layer {
        let mut l = self.clone();
        if l.pe % 2 == 0 {
            l.pe /= 2;
        } else if l.simd % 2 == 0 {
            l.simd /= 2;
        }
        l
    }

    /// Check that the folding parameters divide the layer geometry.
    pub fn folding_valid(&self) -> bool {
        self.pe >= 1
            && self.simd >= 1
            && self.c_out % self.pe == 0
            && self.synapses() % self.simd == 0
    }

    /// Choose the *minimal* PE·SIMD folding that meets a target initiation
    /// interval (cycles/frame). Minimal parallelism keeps weight buffers
    /// deep and narrow, which is exactly what physical RAM mapping
    /// efficiency wants (Fig. 2 read backwards). Ties prefer larger SIMD
    /// (fewer accumulators -> fewer LUTs).
    pub fn fold_to_target(&mut self, target_cycles: u64) {
        let s = self.synapses();
        let pixels = self.ofm() * self.ofm();
        let mut best: Option<(u64, u64, u64)> = None; // (product, pe, simd)
        let mut pe = 1;
        while pe <= self.c_out {
            if self.c_out % pe == 0 {
                let mut simd = 1;
                while simd <= s {
                    if s % simd == 0 {
                        let cycles = (s / simd) * (self.c_out / pe) * pixels;
                        if cycles <= target_cycles {
                            let prod = pe * simd;
                            let better = match best {
                                None => true,
                                Some((bp, _, bs)) => {
                                    prod < bp || (prod == bp && simd > bs)
                                }
                            };
                            if better {
                                best = Some((prod, pe, simd));
                            }
                            break; // larger simd only raises the product
                        }
                    }
                    simd += 1;
                }
            }
            pe += 1;
        }
        // infeasible target: fall back to max parallelism
        let (_, pe, simd) = best.unwrap_or((s * self.c_out, self.c_out, s));
        self.pe = pe;
        self.simd = simd;
    }
}

/// Pipeline-fill contribution of one layer (see [`Network::latency_s`]).
fn stage_fill_cycles(l: &Layer) -> f64 {
    let frac = ((l.k + 1) as f64 / l.ofm() as f64).min(1.0);
    l.cycles_per_frame() as f64 * frac
}

/// A pipeline stage: a plain layer, a pooling stage, or a residual block.
#[derive(Clone, Debug)]
pub enum Stage {
    Mvau(Layer),
    /// Max-pool window/stride (no weights; negligible OCM).
    MaxPool { name: String, window: u64, stride: u64, ifm: u64, channels: u64 },
    /// Residual block: main branch layers + optional bypass conv + join.
    ResBlock { name: String, branch: Vec<Layer>, bypass: Option<Layer> },
}

impl Stage {
    /// Display name of the stage.
    pub fn name(&self) -> &str {
        match self {
            Stage::Mvau(l) => &l.name,
            Stage::MaxPool { name, .. } => name,
            Stage::ResBlock { name, .. } => name,
        }
    }

    /// Activation bits leaving the stage per frame — the tensor a pipeline
    /// cut placed *after* this stage must move to the next device
    /// ([`crate::sharding`] link traffic). Raw-accumulator outputs
    /// (`abits = 0`) count as 1-bit streams, matching
    /// [`crate::memory::activation_bits`].
    pub fn output_bits_per_frame(&self) -> u64 {
        match self {
            Stage::Mvau(l) => l.ofm() * l.ofm() * l.c_out * l.abits.max(1),
            Stage::MaxPool { window, stride, ifm, channels, .. } => {
                let ofm = (ifm - window) / stride + 1;
                ofm * ofm * channels * 2
            }
            Stage::ResBlock { branch, .. } => {
                let l = branch.last().expect("resblock has branch layers");
                l.ofm() * l.ofm() * l.c_out * l.abits.max(1)
            }
        }
    }

    /// All weight-bearing layers in the stage.
    pub fn layers(&self) -> Vec<&Layer> {
        match self {
            Stage::Mvau(l) => vec![l],
            Stage::MaxPool { .. } => vec![],
            Stage::ResBlock { branch, bypass, .. } => {
                let mut v: Vec<&Layer> = branch.iter().collect();
                if let Some(b) = bypass {
                    v.push(b);
                }
                v
            }
        }
    }

    /// Initiation interval of the stage (max over its layers).
    pub fn cycles_per_frame(&self) -> u64 {
        self.layers().iter().map(|l| l.cycles_per_frame()).max().unwrap_or(0)
    }
}

/// A streamlined network.
#[derive(Clone, Debug)]
pub struct Network {
    pub name: String,
    pub stages: Vec<Stage>,
    /// Input image height/width.
    pub image: u64,
    /// Published classification accuracy (metadata from the paper; training
    /// is out of scope — see DESIGN.md substitutions).
    pub top1_pct: f64,
    pub top5_pct: f64,
}

impl Network {
    pub fn layers(&self) -> Vec<&Layer> {
        self.stages.iter().flat_map(|s| s.layers()).collect()
    }

    /// Layers that participate in OCM packing (paper §V exclusions).
    pub fn packable_layers(&self) -> Vec<&Layer> {
        self.layers().into_iter().filter(|l| !l.exclude_from_packing).collect()
    }

    pub fn total_params(&self) -> u64 {
        self.layers().iter().map(|l| l.params()).sum()
    }

    pub fn total_weight_bits(&self) -> u64 {
        self.layers().iter().map(|l| l.weight_bits()).sum()
    }

    /// Total ops per frame (TOp/s numerator of Table II).
    pub fn ops_per_frame(&self) -> u64 {
        self.layers().iter().map(|l| l.ops_per_frame()).sum()
    }

    /// Pipeline initiation interval: slowest stage, in compute cycles.
    pub fn initiation_interval(&self) -> u64 {
        self.stages.iter().map(|s| s.cycles_per_frame()).max().unwrap_or(0)
    }

    /// Frames/s at a compute clock (MHz), steady state.
    pub fn fps(&self, compute_mhz: f64) -> f64 {
        compute_mhz * 1e6 / self.initiation_interval() as f64
    }

    /// Single-frame latency (s): pipeline fill time. A streaming conv stage
    /// starts emitting after ~(K+1) input rows, so it contributes
    /// `II · min(1, (K+1)/OFM)` to the fill; an FC stage needs its whole
    /// input (full II).
    pub fn latency_s(&self, compute_mhz: f64) -> f64 {
        let mut cycles = 0.0f64;
        for s in &self.stages {
            match s {
                Stage::MaxPool { .. } => {}
                Stage::Mvau(l) => cycles += stage_fill_cycles(l),
                Stage::ResBlock { branch, .. } => {
                    cycles += branch.iter().map(|l| stage_fill_cycles(l)).sum::<f64>();
                }
            }
        }
        cycles / (compute_mhz * 1e6)
    }

    /// Contiguous sub-network of stages `[start, end)` — one shard of a
    /// pipeline partition ([`crate::sharding`]). Layer folding and geometry
    /// are untouched; the slice's name records the range so packed-design
    /// caches can key on it.
    pub fn slice(&self, start: usize, end: usize) -> Network {
        assert!(
            start < end && end <= self.stages.len(),
            "bad stage range {start}..{end} of {}",
            self.stages.len()
        );
        Network {
            name: format!("{}[{start}..{end}]", self.name),
            stages: self.stages[start..end].to_vec(),
            image: self.image,
            top1_pct: self.top1_pct,
            top5_pct: self.top5_pct,
        }
    }

    /// Apply ×2 folding to every layer (the paper's F2 variants).
    pub fn fold2(&self) -> Network {
        let mut n = self.clone();
        n.name = format!("{}-F2", self.name);
        for s in &mut n.stages {
            match s {
                Stage::Mvau(l) => *l = l.fold2(),
                Stage::ResBlock { branch, bypass, .. } => {
                    for l in branch.iter_mut() {
                        *l = l.fold2();
                    }
                    if let Some(b) = bypass {
                        *b = b.fold2();
                    }
                }
                Stage::MaxPool { .. } => {}
            }
        }
        n
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layer(pe: u64, simd: u64) -> Layer {
        Layer {
            name: "t".into(),
            kind: LayerKind::Conv,
            k: 3,
            c_in: 64,
            c_out: 128,
            stride: 1,
            pad: 1,
            ifm: 16,
            wbits: 1,
            abits: 2,
            pe,
            simd,
            exclude_from_packing: false,
        }
    }

    #[test]
    fn geometry() {
        let l = layer(4, 8);
        assert_eq!(l.ofm(), 16);
        assert_eq!(l.synapses(), 576);
        assert_eq!(l.params(), 576 * 128);
        assert_eq!(l.buffer_width_bits(), 32);
        assert_eq!(l.buffer_depth(), (576 / 8) * (128 / 4));
        assert!(l.folding_valid());
    }

    #[test]
    fn buffer_conservation() {
        // folding never changes total weight bits, only the shape
        for (pe, simd) in [(1, 1), (4, 8), (128, 576)] {
            let l = layer(pe, simd);
            assert_eq!(
                l.buffer_width_bits() * l.buffer_depth(),
                l.weight_bits()
            );
        }
    }

    #[test]
    fn parallelism_cuts_cycles() {
        assert_eq!(
            layer(1, 1).cycles_per_frame(),
            32 * layer(4, 8).cycles_per_frame()
        );
    }

    #[test]
    fn fold2_halves_parallelism() {
        let l = layer(4, 8).fold2();
        assert_eq!((l.pe, l.simd), (2, 8));
        let l1 = layer(1, 8).fold2();
        assert_eq!((l1.pe, l1.simd), (1, 4));
    }

    #[test]
    fn stride_reduces_ofm() {
        let mut l = layer(1, 1);
        l.stride = 2;
        assert_eq!(l.ofm(), 8);
    }

    #[test]
    fn slice_covers_and_preserves_stages() {
        let net = crate::nn::cnv(crate::nn::CnvVariant::W1A1);
        let n = net.stages.len();
        let a = net.slice(0, 3);
        let b = net.slice(3, n);
        assert_eq!(a.stages.len() + b.stages.len(), n);
        assert_eq!(a.stages[2].name(), net.stages[2].name());
        assert_eq!(b.stages[0].name(), net.stages[3].name());
        // weights are conserved across the cut
        assert_eq!(
            a.total_weight_bits() + b.total_weight_bits(),
            net.total_weight_bits()
        );
        assert!(a.name.contains("[0..3]"), "{}", a.name);
    }

    #[test]
    #[should_panic]
    fn slice_rejects_empty_range() {
        crate::nn::cnv(crate::nn::CnvVariant::W1A1).slice(2, 2);
    }

    #[test]
    fn output_bits_track_tensor_shapes() {
        let l = layer(4, 8); // ofm 16, c_out 128, abits 2
        assert_eq!(Stage::Mvau(l).output_bits_per_frame(), 16 * 16 * 128 * 2);
        let pool = Stage::MaxPool {
            name: "p".into(),
            window: 2,
            stride: 2,
            ifm: 16,
            channels: 64,
        };
        assert_eq!(pool.output_bits_per_frame(), 8 * 8 * 64 * 2);
    }
}
