//! The BNN-Pynq MLP accelerators (SFC / LFC) — the remaining rows of the
//! paper's Table I. Three binarized fully-connected hidden layers on MNIST
//! (28×28 → 3×(256|1024) → 10, padded to 16/64 for folding).

use super::{Layer, LayerKind, Network, Stage};

/// Largest divisor of `n` that is <= `target` (folding must divide).
fn largest_divisor_leq(n: u64, target: u64) -> u64 {
    let mut v = target.min(n).max(1);
    while n % v != 0 {
        v -= 1;
    }
    v
}

/// Build SFC (hidden width 256) or LFC (hidden width 1024) at a weight
/// precision. Folding follows the max-performance BNN-Pynq builds.
pub fn mlp(name: &str, hidden: u64, wbits: u64, abits: u64, pe: u64, simd: u64) -> Network {
    let dims = [(784u64, hidden), (hidden, hidden), (hidden, hidden), (hidden, 16)];
    let mut stages = Vec::new();
    for (i, &(c_in, c_out)) in dims.iter().enumerate() {
        let last = i == dims.len() - 1;
        stages.push(Stage::Mvau(Layer {
            name: format!("fc{}", i + 1),
            kind: LayerKind::FullyConnected,
            k: 1,
            c_in,
            c_out,
            stride: 1,
            pad: 0,
            ifm: 1,
            wbits,
            abits: if last { 0 } else { abits },
            pe: largest_divisor_leq(c_out, pe),
            simd: largest_divisor_leq(c_in, simd),
            // first layer consumes 8-bit images, last layer classifier
            exclude_from_packing: i == 0 || last,
        }));
    }
    Network {
        name: name.to_string(),
        stages,
        image: 28,
        top1_pct: if hidden >= 1024 { 98.4 } else { 98.0 }, // published MNIST
        top5_pct: 100.0,
    }
}

/// SFC-W1A1: small MLP, 256-wide hidden layers.
pub fn sfc_w1a1() -> Network {
    mlp("SFC-W1A1", 256, 1, 1, 16, 16)
}

/// LFC-W1A1: large MLP, 1024-wide hidden layers (the Table I row with the
/// highest BRAM pressure).
pub fn lfc_w1a1() -> Network {
    mlp("LFC-W1A1", 1024, 1, 1, 32, 32)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::folding::network_resources;

    #[test]
    fn parameter_counts() {
        // LFC: 784*1024 + 2*1024^2 + 1024*16 = 2,916,352
        assert_eq!(lfc_w1a1().total_params(), 784 * 1024 + 2 * 1024 * 1024 + 1024 * 16);
        assert_eq!(sfc_w1a1().total_params(), 784 * 256 + 2 * 256 * 256 + 256 * 16);
    }

    #[test]
    fn lfc_is_bram_bound_on_7020() {
        // Table I: the MLP rows show BRAM as the binding resource
        let dev = crate::device::zynq_7020();
        let r = network_resources(&lfc_w1a1(), &dev);
        assert!(r.bram_pct(&dev) > r.lut_pct(&dev) / 2.0);
        assert!(r.bram_pct(&dev) > 50.0, "bram {}%", r.bram_pct(&dev));
    }

    #[test]
    fn foldings_valid() {
        for n in [sfc_w1a1(), lfc_w1a1()] {
            for l in n.layers() {
                assert!(l.folding_valid(), "{}", l.name);
            }
        }
    }

    #[test]
    fn mlps_pack_like_convs() {
        let net = lfc_w1a1();
        let bufs = crate::memory::weight_buffers(&net, 1);
        let items = crate::memory::all_columns(&bufs);
        let c = crate::packing::Constraints::new(4, false);
        let (p, r) = crate::packing::run_packer(
            &crate::packing::ffd::Ffd::new(),
            &items,
            &c,
        );
        p.validate(&items, &c).unwrap();
        assert!(r.brams <= crate::memory::direct_brams(&bufs));
    }
}
