//! Quantized ResNet-50 v1.5 as a streamlined dataflow graph (paper §III).
//!
//! 16 residual blocks in stages of 3/4/6/3; each block is 1×1 → 3×3 → 1×1
//! with an optional 1×1 downsample on the bypass branch (4 "type A" blocks).
//! Channel progression 256 → 512 → 1024 → 2048; stride-2 in the 3×3 conv of
//! each stage's first block (v1.5). Weights within resblocks are binary
//! (W1A2) or ternary (W2A2); the first 7×7 conv and final FC are 8-bit and
//! excluded from OCM packing (§V).

use super::{Layer, LayerKind, Network, Stage};

/// Default target initiation interval (compute cycles/frame) for the
/// full-size RN50 folding solution: the paper's U250 operating point is
/// 2703 FPS @ 195 MHz => ~72k cycles (Table II).
pub const RN50_TARGET_II: u64 = 72_000;

/// Build quantized ResNet-50 (full-size shapes: 224×224 ImageNet input).
pub fn resnet50(wbits: u64) -> Network {
    resnet50_scaled(wbits, 1.0, 224, RN50_TARGET_II)
}

/// Channel-scaled variant (the executable `rn50_lite` artifact uses 0.25).
pub fn resnet50_scaled(
    wbits: u64,
    width_scale: f64,
    image: u64,
    target_ii: u64,
) -> Network {
    let ch = |c: u64| -> u64 { ((c as f64 * width_scale) as u64).max(1) };
    let stage_mid = [ch(64), ch(128), ch(256), ch(512)];
    let stage_n = [3usize, 4, 6, 3];

    let mut stages: Vec<Stage> = Vec::new();

    // top: 7x7/2 conv (8-bit weights) + 3x3/2 maxpool
    let c0 = stage_mid[0];
    stages.push(Stage::Mvau(Layer {
        name: "conv_top".into(),
        kind: LayerKind::Conv,
        k: 7,
        c_in: 3,
        c_out: c0,
        stride: 2,
        pad: 3,
        ifm: image,
        wbits: 8,
        abits: 4,
        // 8-bit MACs in DSP slices; PE*SIMD = 1568 puts the top conv just
        // at the pipeline II and lands near Table II's 1611 DSPs on U250
        pe: c0.min(32),
        simd: 49,
        exclude_from_packing: true,
    }));
    let mut fm = image / 2; // after conv_top
    stages.push(Stage::MaxPool {
        name: "pool_top".into(),
        window: 3,
        stride: 2,
        ifm: fm,
        channels: c0,
    });
    fm = (fm + 1) / 2;

    let mut c_in = c0;
    for (s, (&mid, &n)) in stage_mid.iter().zip(stage_n.iter()).enumerate() {
        let c_out = mid * 4;
        for b in 0..n {
            let first = b == 0;
            let stride = if first && s > 0 { 2 } else { 1 };
            let name = format!("res{}{}", s + 2, (b'a' + b as u8) as char);
            // PE/SIMD are solved below via fold_to_target (minimal
            // parallelism meeting the throughput target => deepest buffers)
            let branch = vec![
                Layer {
                    name: format!("{name}_c1"),
                    kind: LayerKind::Conv,
                    k: 1,
                    c_in,
                    c_out: mid,
                    stride: 1,
                    pad: 0,
                    ifm: fm,
                    wbits,
                    abits: 2,
                    pe: 1,
                    simd: 1,
                    exclude_from_packing: false,
                },
                Layer {
                    name: format!("{name}_c2"),
                    kind: LayerKind::Conv,
                    k: 3,
                    c_in: mid,
                    c_out: mid,
                    stride,
                    pad: 1,
                    ifm: fm,
                    wbits,
                    abits: 2,
                    pe: 1,
                    simd: 1,
                    exclude_from_packing: false,
                },
                Layer {
                    name: format!("{name}_c3"),
                    kind: LayerKind::Conv,
                    k: 1,
                    c_in: mid,
                    c_out,
                    stride: 1,
                    pad: 0,
                    ifm: fm / stride,
                    wbits,
                    abits: 4,
                    pe: 1,
                    simd: 1,
                    exclude_from_packing: false,
                },
            ];
            let bypass = if first {
                Some(Layer {
                    name: format!("{name}_cb"),
                    kind: LayerKind::Conv,
                    k: 1,
                    c_in,
                    c_out,
                    stride,
                    pad: 0,
                    ifm: fm,
                    wbits,
                    abits: 4,
                    pe: 1,
                    simd: 1,
                    exclude_from_packing: false,
                })
            } else {
                None
            };
            stages.push(Stage::ResBlock { name, branch, bypass });
            c_in = c_out;
            fm /= stride;
        }
    }

    // solve the folding: minimal PE*SIMD per resblock conv meeting the
    // target II (paper section III.B's throughput-maximal folding solution)
    for st in &mut stages {
        if let Stage::ResBlock { branch, bypass, .. } = st {
            for l in branch.iter_mut() {
                l.fold_to_target(target_ii);
            }
            if let Some(b) = bypass {
                b.fold_to_target(target_ii);
            }
        }
    }

    // bottom: global average pool (free) + 8-bit FC, stored off-BRAM
    stages.push(Stage::Mvau(Layer {
        name: "fc_out".into(),
        kind: LayerKind::FullyConnected,
        k: 1,
        c_in,
        c_out: 1008, // 1000 classes padded for folding
        stride: 1,
        pad: 0,
        ifm: 1,
        wbits: 8,
        abits: 0,
        pe: 16,
        simd: 8,
        exclude_from_packing: true,
    }));

    let (top1, top5) = if wbits == 1 { (67.27, 87.64) } else { (69.85, 89.38) };
    Network {
        name: format!(
            "RN50-W{}A2{}",
            wbits,
            if width_scale != 1.0 { "-lite" } else { "" }
        ),
        stages,
        image,
        top1_pct: top1,
        top5_pct: top5,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sixteen_resblocks_four_downsamples() {
        let n = resnet50(1);
        let blocks: Vec<_> = n
            .stages
            .iter()
            .filter_map(|s| match s {
                Stage::ResBlock { bypass, .. } => Some(bypass.is_some()),
                _ => None,
            })
            .collect();
        assert_eq!(blocks.len(), 16);
        assert_eq!(blocks.iter().filter(|&&d| d).count(), 4);
    }

    #[test]
    fn conv_counts_per_paper() {
        // 4 blocks x 4 convs + 12 blocks x 3 convs = 52 resblock convs
        let n = resnet50(1);
        let resconvs = n
            .layers()
            .iter()
            .filter(|l| !l.exclude_from_packing)
            .count();
        assert_eq!(resconvs, 52);
    }

    #[test]
    fn channel_progression() {
        let n = resnet50(1);
        let mut outs: Vec<u64> = n
            .stages
            .iter()
            .filter_map(|s| match s {
                Stage::ResBlock { branch, .. } => Some(branch[2].c_out),
                _ => None,
            })
            .collect();
        outs.dedup();
        assert_eq!(outs, vec![256, 512, 1024, 2048]);
    }

    #[test]
    fn resblock_params_about_23m() {
        let n = resnet50(1);
        let p: u64 = n.packable_layers().iter().map(|l| l.params()).sum();
        assert!(p > 20_000_000 && p < 27_000_000, "params {p}");
    }

    #[test]
    fn total_ops_about_8gop_per_frame() {
        // ResNet-50 @224 is ~4 GMAC = ~8 GOp per frame; our streamlined
        // variant is within a factor ~1.3 (padded fc + v1.5 conv placement)
        let n = resnet50(1);
        let ops = n.ops_per_frame() as f64;
        assert!(ops > 5e9 && ops < 11e9, "ops {ops}");
    }

    #[test]
    fn feature_map_exits_at_7() {
        let n = resnet50(1);
        let last = n
            .stages
            .iter()
            .rev()
            .find_map(|s| match s {
                Stage::ResBlock { branch, .. } => Some(branch[2].ifm),
                _ => None,
            })
            .unwrap();
        assert_eq!(last, 7);
    }

    #[test]
    fn memory_grows_towards_output() {
        // Fig. 4: memory utilization increases dramatically towards the
        // output, proportional to channels
        let n = resnet50(1);
        let per_block: Vec<u64> = n
            .stages
            .iter()
            .filter_map(|s| match s {
                Stage::ResBlock { branch, bypass, .. } => Some(
                    branch.iter().map(|l| l.weight_bits()).sum::<u64>()
                        + bypass.as_ref().map_or(0, |l| l.weight_bits()),
                ),
                _ => None,
            })
            .collect();
        assert!(per_block.last().unwrap() > &(8 * per_block.first().unwrap()));
    }

    #[test]
    fn foldings_valid_and_lite_consistent() {
        for n in [resnet50(1), resnet50_scaled(1, 0.25, 32, 4_000)] {
            for l in n.layers() {
                assert!(l.folding_valid(), "{} pe={} simd={}", l.name, l.pe, l.simd);
            }
        }
    }
}
