//! The BNN-Pynq CNV topology (paper §V "embedded-class" accelerators).
//!
//! CNV: CIFAR-10, 32×32×3 input; six 3×3 VALID convolutions with maxpool
//! after conv pairs 2 and 4; three fully-connected layers (the last padded
//! to 16 outputs by FINN). Published accuracies: 79.54% (W1A1) and 84.8%
//! (W2A2) — paper §V.
//!
//! PE/SIMD folding follows the max-performance BNN-Pynq build for Zynq 7020
//! (the Table I configurations).

use super::{Layer, LayerKind, Network, Stage};

/// The two CNV precision variants the paper packs (plus W1A2 used in
/// BNN-Pynq's Table I row set).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CnvVariant {
    W1A1,
    W1A2,
    W2A2,
}

impl CnvVariant {
    pub fn wbits(self) -> u64 {
        match self {
            CnvVariant::W1A1 | CnvVariant::W1A2 => 1,
            CnvVariant::W2A2 => 2,
        }
    }

    pub fn abits(self) -> u64 {
        match self {
            CnvVariant::W1A1 => 1,
            CnvVariant::W1A2 | CnvVariant::W2A2 => 2,
        }
    }

    pub fn suffix(self) -> &'static str {
        match self {
            CnvVariant::W1A1 => "W1A1",
            CnvVariant::W1A2 => "W1A2",
            CnvVariant::W2A2 => "W2A2",
        }
    }
}

struct ConvSpec {
    name: &'static str,
    c_in: u64,
    c_out: u64,
    ifm: u64,
    pe: u64,
    simd: u64,
}

struct FcSpec {
    name: &'static str,
    c_in: u64,
    c_out: u64,
    pe: u64,
    simd: u64,
}

/// Build the CNV network for a precision variant.
///
/// W2A2 halves the PE parallelism of the wide convolutions: the 2-bit
/// datapath doubles per-synapse LUT cost, and BNN-Pynq's W2A2 build trades
/// throughput to stay within the 7020 (its Table IV weight subsystem is
/// 208 BRAMs at 79.9% efficiency — a deeper, narrower shape than W1A1's).
pub fn cnv(variant: CnvVariant) -> Network {
    let wbits = variant.wbits();
    let abits = variant.abits();
    let half = |p: u64| if wbits == 2 { (p / 2).max(1) } else { p };

    // (BNN-Pynq cnvW1A1 max-performance folding on Zynq 7020)
    let convs = [
        ConvSpec { name: "conv1", c_in: 3, c_out: 64, ifm: 32, pe: 16, simd: 3 },
        ConvSpec { name: "conv2", c_in: 64, c_out: 64, ifm: 30, pe: half(32), simd: 32 },
        ConvSpec { name: "conv3", c_in: 64, c_out: 128, ifm: 14, pe: half(16), simd: 32 },
        ConvSpec { name: "conv4", c_in: 128, c_out: 128, ifm: 12, pe: half(16), simd: 32 },
        ConvSpec { name: "conv5", c_in: 128, c_out: 256, ifm: 5, pe: half(4), simd: 32 },
        ConvSpec { name: "conv6", c_in: 256, c_out: 256, ifm: 3, pe: 1, simd: 32 },
    ];
    let fcs = [
        FcSpec { name: "fc1", c_in: 256, c_out: 512, pe: 1, simd: 4 },
        FcSpec { name: "fc2", c_in: 512, c_out: 512, pe: 1, simd: 8 },
        FcSpec { name: "fc3", c_in: 512, c_out: 16, pe: 4, simd: 1 },
    ];

    let mut stages = Vec::new();
    for (i, c) in convs.iter().enumerate() {
        stages.push(Stage::Mvau(Layer {
            name: c.name.into(),
            kind: LayerKind::Conv,
            k: 3,
            c_in: c.c_in,
            c_out: c.c_out,
            stride: 1,
            pad: 0,
            ifm: c.ifm,
            wbits,
            abits,
            pe: c.pe,
            simd: c.simd,
            // paper §V: first layer excluded (small, 8-bit input path)
            exclude_from_packing: i == 0,
        }));
        if c.name == "conv2" || c.name == "conv4" {
            let ofm = c.ifm - 2;
            stages.push(Stage::MaxPool {
                name: format!("pool_{}", c.name),
                window: 2,
                stride: 2,
                ifm: ofm,
                channels: c.c_out,
            });
        }
    }
    for (i, f) in fcs.iter().enumerate() {
        stages.push(Stage::Mvau(Layer {
            name: f.name.into(),
            kind: LayerKind::FullyConnected,
            k: 1,
            c_in: f.c_in,
            c_out: f.c_out,
            stride: 1,
            pad: 0,
            ifm: 1,
            wbits,
            abits: if i == 2 { 0 } else { abits },
            pe: f.pe,
            simd: f.simd,
            // last FC weights live in URAM/DDR per §V
            exclude_from_packing: i == 2,
        }));
    }

    let (top1, top5) = match variant {
        CnvVariant::W1A1 => (79.54, 94.0),
        CnvVariant::W1A2 => (82.7, 95.0),
        CnvVariant::W2A2 => (84.80, 96.0),
    };
    Network {
        name: format!("CNV-{}", variant.suffix()),
        stages,
        image: 32,
        top1_pct: top1,
        top5_pct: top5,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn weight_total_matches_bnn_pynq() {
        let n = cnv(CnvVariant::W1A1);
        // published CNV total is 1,542,848 with a 10-wide final layer; FINN
        // pads fc3 to 16 outputs: +512*6
        assert_eq!(n.total_params(), 1_542_848 + 512 * 6);
    }

    #[test]
    fn feature_map_chain_consistent() {
        let n = cnv(CnvVariant::W1A1);
        // conv1 32->30, conv2 30->28, pool ->14, conv3 ->12, conv4 ->10,
        // pool ->5, conv5 ->3, conv6 ->1
        let dims: Vec<u64> = n
            .layers()
            .iter()
            .filter(|l| l.kind == LayerKind::Conv)
            .map(|l| l.ofm())
            .collect();
        assert_eq!(dims, vec![30, 28, 12, 10, 3, 1]);
    }

    #[test]
    fn foldings_are_valid() {
        for v in [CnvVariant::W1A1, CnvVariant::W1A2, CnvVariant::W2A2] {
            for l in cnv(v).layers() {
                assert!(l.folding_valid(), "{}", l.name);
            }
        }
    }

    #[test]
    fn w2a2_doubles_weight_bits() {
        assert_eq!(
            cnv(CnvVariant::W2A2).total_weight_bits(),
            2 * cnv(CnvVariant::W1A1).total_weight_bits()
        );
    }

    #[test]
    fn packing_exclusions() {
        let n = cnv(CnvVariant::W1A1);
        let pk = n.packable_layers();
        assert_eq!(pk.len(), n.layers().len() - 2);
        assert!(pk.iter().all(|l| l.name != "conv1" && l.name != "fc3"));
    }

    #[test]
    fn ii_dominated_by_a_conv() {
        let n = cnv(CnvVariant::W1A1);
        let ii = n.initiation_interval();
        assert!(ii > 0);
        // at 100 MHz the BNN-Pynq CNV reaches O(10^2..10^4) FPS
        let fps = n.fps(100.0);
        assert!(fps > 100.0 && fps < 50_000.0, "fps {fps}");
    }
}
