//! Dataflow pipeline simulator: frame-level throughput/latency plus a
//! discrete-time stream simulation of the resblock branch/join (the paper's
//! "relatively deep FIFO on the bypass path", §III.B).
//!
//! The analytic model (initiation interval = slowest stage) matches FINN-R;
//! the stream simulation validates it and sizes the bypass FIFOs: with a
//! too-shallow FIFO the join stalls the whole pipeline and throughput drops
//! below the analytic bound.
//!
//! Beyond the single-chain ns-domain engine ([`pipeline`]), the [`event`] +
//! [`fleet`] pair generalizes simulation to a whole serving fleet: a
//! deterministic discrete-event executor for any
//! [`crate::coordinator::Deployment`] (bounded queues, batchers, in-flight
//! windows, RR/JSQ/SWRR admission, chain links, virtual-tick control
//! plane) that sweeps thousands of chain groups and millions of requests
//! in wall-clock seconds.

pub mod event;
pub mod fleet;
pub mod pipeline;

pub use event::EventQueue;
pub use fleet::{FleetSim, SimBackend, SimConfig, SimControl, SimReport};
pub use pipeline::{
    simulate_chain, simulate_network, simulate_sharded, ChainResult, ChainStage,
    PipelineResult, ShardedResult,
};

use crate::nn::{Network, Stage};

/// Analytic performance summary (the Table II quantities).
#[derive(Clone, Debug)]
pub struct PerfEstimate {
    pub fps: f64,
    pub latency_ms: f64,
    pub tops: f64,
    pub ii_cycles: u64,
    pub bottleneck: String,
}

/// Analytic FPS / latency / TOp/s at a compute clock.
pub fn estimate(net: &Network, compute_mhz: f64) -> PerfEstimate {
    let ii = net.initiation_interval();
    let fps = net.fps(compute_mhz);
    let bottleneck = net
        .stages
        .iter()
        .max_by_key(|s| s.cycles_per_frame())
        .map(|s| match s {
            Stage::Mvau(l) => l.name.clone(),
            Stage::MaxPool { name, .. } => name.clone(),
            Stage::ResBlock { name, branch, bypass } => {
                let mut worst = ("", 0u64);
                for l in branch.iter().chain(bypass.iter()) {
                    if l.cycles_per_frame() > worst.1 {
                        worst = (&l.name, l.cycles_per_frame());
                    }
                }
                format!("{name}/{}", worst.0)
            }
        })
        .unwrap_or_default();
    PerfEstimate {
        fps,
        latency_ms: net.latency_s(compute_mhz) * 1e3,
        tops: net.ops_per_frame() as f64 * fps / 1e12,
        ii_cycles: ii,
        bottleneck,
    }
}

/// Bypass FIFO depth (in pixels) required for a resblock to run stall-free:
/// the branch pipeline holds `latency(branch) - latency(bypass)` pixels in
/// flight that the join must buffer on the bypass side.
pub fn bypass_fifo_pixels(branch_cycles: &[u64], bypass_cycles: u64, ii: u64) -> u64 {
    let branch_total: u64 = branch_cycles.iter().sum();
    (branch_total.saturating_sub(bypass_cycles)) / ii.max(1) + 1
}

/// Discrete-time simulation of one branch/join structure.
///
/// Tokens (pixel groups) enter at rate 1/`ii` cycles; the branch path is a
/// chain of stages each with the given per-token service cycles and
/// single-token buffers between them; the bypass path is a FIFO of
/// `fifo_depth` tokens. The join fires when both sides present a token.
/// Returns achieved throughput relative to the ideal 1/`ii`.
pub fn simulate_resblock_join(
    branch_stage_cycles: &[u64],
    fifo_depth: usize,
    ii: u64,
    tokens: u64,
) -> f64 {
    #[derive(Clone, Copy)]
    struct InFlight {
        done_at: u64,
    }

    let n_stages = branch_stage_cycles.len();
    let mut t: u64 = 0;
    let mut produced: u64 = 0; // tokens emitted by source
    let mut joined: u64 = 0;
    // branch: at most one token per stage (II-bound stages)
    let mut branch: Vec<Option<InFlight>> = vec![None; n_stages];
    let mut branch_out: u64 = 0; // tokens waiting at join from branch
    let mut bypass_fifo: u64 = 0; // tokens waiting in bypass FIFO
    let mut next_emit: u64 = 0;
    let horizon = tokens * ii * (n_stages as u64 + 4) + 10_000;

    while joined < tokens && t < horizon {
        // stage completions, last stage first (frees upstream slots)
        for s in (0..n_stages).rev() {
            if let Some(f) = branch[s] {
                if f.done_at <= t {
                    if s + 1 < n_stages {
                        if branch[s + 1].is_none() {
                            branch[s + 1] = Some(InFlight {
                                done_at: t + branch_stage_cycles[s + 1],
                            });
                            branch[s] = None;
                        }
                    } else {
                        branch_out += 1;
                        branch[s] = None;
                    }
                }
            }
        }
        // source emission: needs a free first stage AND bypass FIFO space
        if produced < tokens
            && t >= next_emit
            && branch[0].is_none()
            && (bypass_fifo as usize) < fifo_depth
        {
            branch[0] = Some(InFlight { done_at: t + branch_stage_cycles[0] });
            bypass_fifo += 1;
            produced += 1;
            next_emit = t + ii;
        }
        // join
        if branch_out > 0 && bypass_fifo > 0 {
            branch_out -= 1;
            bypass_fifo -= 1;
            joined += 1;
        }
        t += 1;
    }
    let ideal_cycles = tokens * ii + branch_stage_cycles.iter().sum::<u64>();
    ideal_cycles as f64 / t.max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::{cnv, resnet50, CnvVariant};

    #[test]
    fn rn50_estimate_matches_table_ii_shape() {
        // Table II: RN50-W1A2 on U250 @195 MHz: 2703 FPS, 1.9 ms, 18.3 TOp/s
        let net = resnet50(1);
        let e = estimate(&net, 195.0);
        assert!((e.fps - 2703.0).abs() / 2703.0 < 0.15, "fps {}", e.fps);
        assert!(e.latency_ms > 0.5 && e.latency_ms < 4.0, "lat {}", e.latency_ms);
        assert!(e.tops > 10.0 && e.tops < 30.0, "tops {}", e.tops);
    }

    #[test]
    fn cnv_estimate_reasonable() {
        let e = estimate(&cnv(CnvVariant::W1A1), 100.0);
        assert!(e.fps > 1_000.0 && e.fps < 10_000.0, "fps {}", e.fps);
        assert!(!e.bottleneck.is_empty());
    }

    #[test]
    fn deep_fifo_reaches_analytic_throughput() {
        // branch of 3 stages, each II-bound, with ample FIFO: ~full rate
        let th = simulate_resblock_join(&[100, 100, 100], 16, 100, 200);
        assert!(th > 0.95, "throughput {th}");
    }

    #[test]
    fn shallow_fifo_stalls_pipeline() {
        let deep = simulate_resblock_join(&[100, 100, 100], 16, 100, 200);
        let shallow = simulate_resblock_join(&[100, 100, 100], 1, 100, 200);
        assert!(
            shallow < deep - 0.1,
            "shallow {shallow} should stall vs deep {deep}"
        );
    }

    #[test]
    fn fifo_sizing_rule_is_sufficient() {
        let stages = [250u64, 400, 130];
        let ii = 400;
        let depth = bypass_fifo_pixels(&stages, 0, ii) as usize;
        let th = simulate_resblock_join(&stages, depth, ii, 150);
        assert!(th > 0.93, "sized-FIFO throughput {th} (depth {depth})");
    }

    #[test]
    fn folding_by_two_halves_fps() {
        let net = resnet50(1);
        let f2 = net.fold2();
        let base = estimate(&net, 195.0).fps;
        let folded = estimate(&f2, 195.0).fps;
        let ratio = base / folded;
        assert!((1.7..2.4).contains(&ratio), "F2 ratio {ratio}");
    }
}
