//! Whole-network streaming pipeline simulator.
//!
//! Discrete-time, frame-granular with fractional progress: every stage is a
//! server with service time = its initiation interval (cycles/frame);
//! stages are connected by bounded FIFOs (frames); resblocks fork into a
//! branch chain and a bypass FIFO that re-join (§III.B). The simulator
//! validates the analytic model (steady-state FPS = F_c / max II) and
//! exposes what the analytic model cannot: warm-up transients, FIFO
//! occupancy high-water marks (FIFO sizing), and the slowdown from
//! under-provisioned bypass FIFOs.
//!
//! The same server-chain core runs in two time domains: compute cycles for
//! one accelerator ([`simulate_network`]) and nanoseconds for a
//! multi-device sharded pipeline ([`simulate_sharded`]), where each shard
//! runs at its own post-closure clock and cuts insert store-and-forward
//! link stages bounded by inter-device FIFOs.

use crate::nn::{Network, Stage};
use crate::sharding::ShardPlan;

/// One simulated pipeline stage.
#[derive(Clone, Debug)]
struct SimStage {
    name: String,
    /// Service time in compute cycles per frame.
    ii: u64,
    /// Completion time of the frame currently in service (None = idle).
    busy_until: Option<u64>,
    /// Frames waiting at the input.
    queue: u64,
    queue_cap: u64,
    /// High-water mark of the input queue.
    hwm: u64,
    /// Frames completed.
    done: u64,
}

impl SimStage {
    fn new(name: String, ii: u64, queue_cap: u64) -> SimStage {
        SimStage { name, ii: ii.max(1), busy_until: None, queue: 0, queue_cap, hwm: 0, done: 0 }
    }

    fn can_accept(&self) -> bool {
        self.queue < self.queue_cap
    }

    fn push(&mut self, _t: u64) {
        self.queue += 1;
        self.hwm = self.hwm.max(self.queue);
    }
}

/// Simulation result.
#[derive(Clone, Debug)]
pub struct PipelineResult {
    /// Steady-state throughput in frames per kilocycle.
    pub frames_per_kcycle: f64,
    /// Cycles from first injection to first output (fill latency).
    pub first_out_cycles: u64,
    /// Total cycles to drain all frames.
    pub total_cycles: u64,
    /// Per-stage input-queue high-water marks.
    pub queue_hwm: Vec<(String, u64)>,
    /// Throughput relative to the analytic bound (1.0 = matches).
    pub vs_analytic: f64,
}

/// Flatten the network into a serial chain (resblock branches are serial in
/// time — the bypass FIFO is modelled by a larger queue at the join).
fn flatten(net: &Network, bypass_cap: u64) -> Vec<SimStage> {
    let mut out = Vec::new();
    for s in &net.stages {
        match s {
            Stage::Mvau(l) => {
                out.push(SimStage::new(l.name.clone(), l.cycles_per_frame(), 2))
            }
            Stage::MaxPool { name, .. } => out.push(SimStage::new(name.clone(), 1, 2)),
            Stage::ResBlock { name, branch, .. } => {
                for l in branch {
                    out.push(SimStage::new(l.name.clone(), l.cycles_per_frame(), 2));
                }
                // the join: service = instantaneous, but its queue models
                // the bypass FIFO capacity
                out.push(SimStage::new(format!("{name}_join"), 1, bypass_cap));
            }
        }
    }
    out
}

/// Drive `frames` frames through a server chain. Returns
/// `(total time, first output time, sink completion times)`.
fn run(stages: &mut [SimStage], frames: u64) -> (u64, Option<u64>, Vec<u64>) {
    let n = stages.len();
    assert!(n > 0 && frames > 0);
    let max_ii = stages.iter().map(|s| s.ii).max().unwrap();

    // event-driven over completion times, advancing in II-sized hops
    let mut t: u64 = 0;
    let mut injected = 0u64;
    let mut first_out = None;
    let mut out_times = Vec::with_capacity(frames as usize);
    let horizon = frames * max_ii * 4 + stages.iter().map(|s| s.ii).sum::<u64>() * 2;

    while stages[n - 1].done < frames && t < horizon {
        // 1. retire completions back-to-front, push downstream if space
        for i in (0..n).rev() {
            if let Some(done_at) = stages[i].busy_until {
                if done_at <= t {
                    let can = i + 1 >= n || stages[i + 1].can_accept();
                    if can {
                        stages[i].busy_until = None;
                        stages[i].done += 1;
                        if i + 1 < n {
                            stages[i + 1].push(t);
                        } else {
                            if first_out.is_none() {
                                first_out = Some(t);
                            }
                            out_times.push(t);
                        }
                    }
                }
            }
        }
        // 2. start service where idle and queued
        for i in 0..n {
            if stages[i].busy_until.is_none() && stages[i].queue > 0 {
                stages[i].queue -= 1;
                let ii = stages[i].ii;
                stages[i].busy_until = Some(t + ii);
            }
        }
        // 3. inject at the source
        if injected < frames && stages[0].can_accept() {
            stages[0].push(t);
            injected += 1;
        }
        // 4. advance to the next interesting time
        let next = stages
            .iter()
            .filter_map(|s| s.busy_until)
            .filter(|&d| d > t)
            .min()
            .unwrap_or(t + 1);
        t = next.max(t + 1);
    }
    (t, first_out, out_times)
}

/// Summarize a finished run into the [`PipelineResult`] quantities.
fn summarize(
    stages: &[SimStage],
    frames: u64,
    total: u64,
    first_out: Option<u64>,
    out_times: &[u64],
) -> PipelineResult {
    let max_ii = stages.iter().map(|s| s.ii).max().unwrap();
    // steady-state throughput: measured between the first and last output
    // so the pipeline-fill transient does not dilute it
    let first = first_out.unwrap_or(0);
    let last_out = out_times.last().copied().unwrap_or(0);
    let steady_cycles = last_out.saturating_sub(first).max(1);
    let fpk = if frames > 1 {
        (frames - 1) as f64 / (steady_cycles as f64 / 1000.0)
    } else {
        frames as f64 / (total as f64 / 1000.0)
    };
    let analytic_fpk = 1000.0 / max_ii as f64;
    PipelineResult {
        frames_per_kcycle: fpk,
        first_out_cycles: first_out.unwrap_or(total),
        total_cycles: total,
        queue_hwm: stages.iter().map(|s| (s.name.clone(), s.hwm)).collect(),
        vs_analytic: fpk / analytic_fpk,
    }
}

/// Run `frames` frames through the network; `bypass_cap` is the per-join
/// bypass FIFO capacity in frames (the paper's deep-FIFO knob).
pub fn simulate_network(net: &Network, frames: u64, bypass_cap: u64) -> PipelineResult {
    let mut stages = flatten(net, bypass_cap);
    let (total, first_out, out_times) = run(&mut stages, frames);
    summarize(&stages, frames, total, first_out, &out_times)
}

/// One stage of a generic service chain. Service time is in arbitrary
/// integer time units — [`simulate_network`] uses compute cycles,
/// [`simulate_sharded`] nanoseconds.
#[derive(Clone, Debug)]
pub struct ChainStage {
    pub name: String,
    pub service: u64,
    pub queue_cap: u64,
}

/// Result of a generic chain run: the [`PipelineResult`] summary plus the
/// raw sink completion times for warm-up-free rate measurement.
#[derive(Clone, Debug)]
pub struct ChainResult {
    pub result: PipelineResult,
    /// Completion time of each frame at the sink (same units as service).
    pub out_times: Vec<u64>,
}

impl ChainResult {
    /// Steady-state completion rate (frames per time unit) measured over
    /// the second half of the outputs, excluding the pipeline-fill and
    /// queue-settling transients entirely.
    pub fn steady_rate(&self) -> f64 {
        let n = self.out_times.len();
        if n < 2 {
            return 0.0;
        }
        let h = n / 2;
        let span = self.out_times[n - 1].saturating_sub(self.out_times[h]) as f64;
        (n - 1 - h) as f64 / span.max(1.0)
    }
}

/// Simulate an arbitrary server chain for `frames` frames.
pub fn simulate_chain(chain: &[ChainStage], frames: u64) -> ChainResult {
    let mut stages: Vec<SimStage> = chain
        .iter()
        .map(|c| SimStage::new(c.name.clone(), c.service, c.queue_cap.max(1)))
        .collect();
    let (total, first_out, out_times) = run(&mut stages, frames);
    let result = summarize(&stages, frames, total, first_out, &out_times);
    ChainResult { result, out_times }
}

/// Result of a sharded-pipeline simulation (nanosecond domain).
#[derive(Clone, Debug)]
pub struct ShardedResult {
    /// Steady-state frames/s (second-half measurement window).
    pub fps: f64,
    /// Measured FPS relative to the plan's analytic bottleneck
    /// ([`ShardPlan::fps`]); 1.0 = the staged pipeline sustains exactly
    /// the bottleneck initiation interval.
    pub vs_analytic: f64,
    /// Nanoseconds from first injection to first output (fill latency
    /// across every shard and link).
    pub first_out_ns: u64,
    /// Per-stage input-queue high-water marks (stages and links).
    pub queue_hwm: Vec<(String, u64)>,
}

/// Simulate a [`ShardPlan`] end to end: every network stage is a server
/// running at its shard's effective clock, every cut inserts a
/// store-and-forward link stage, and each link's egress feeds the next
/// shard through a bounded FIFO of `link_fifo` frames (the inter-device
/// FIFO of the plan; intra-shard queues stay at depth 2).
pub fn simulate_sharded(
    net: &Network,
    plan: &ShardPlan,
    frames: u64,
    link_fifo: u64,
) -> ShardedResult {
    assert!(frames >= 8, "need frames >= 8 for a steady-state window");
    let mut chain: Vec<ChainStage> = Vec::new();
    for (j, shard) in plan.shards.iter().enumerate() {
        if j > 0 {
            let l = &plan.links[j - 1];
            chain.push(ChainStage {
                name: format!("link{}", j - 1),
                service: (l.seconds_per_frame * 1e9).round().max(1.0) as u64,
                queue_cap: link_fifo.max(1),
            });
        }
        for si in shard.stages.0..shard.stages.1 {
            let s = &net.stages[si];
            let ns = s.cycles_per_frame().max(1) as f64 * 1e3 / shard.effective_mhz;
            // the first stage after a link owns the ingress FIFO
            let cap = if j > 0 && si == shard.stages.0 {
                link_fifo.max(1)
            } else {
                2
            };
            chain.push(ChainStage {
                name: s.name().to_string(),
                service: ns.round().max(1.0) as u64,
                queue_cap: cap,
            });
        }
    }
    let r = simulate_chain(&chain, frames);
    let fps = r.steady_rate() * 1e9;
    ShardedResult {
        fps,
        vs_analytic: fps / plan.fps,
        first_out_ns: r.result.first_out_cycles,
        queue_hwm: r.result.queue_hwm,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::{cnv, resnet50, CnvVariant};

    #[test]
    fn cnv_pipeline_matches_analytic_ii() {
        let net = cnv(CnvVariant::W1A1);
        let r = simulate_network(&net, 40, 8);
        assert!(
            (0.9..=1.02).contains(&r.vs_analytic),
            "throughput {} of analytic",
            r.vs_analytic
        );
    }

    #[test]
    fn rn50_pipeline_matches_analytic_ii() {
        let net = resnet50(1);
        let r = simulate_network(&net, 25, 8);
        assert!(
            (0.85..=1.02).contains(&r.vs_analytic),
            "throughput {} of analytic",
            r.vs_analytic
        );
    }

    #[test]
    fn fill_latency_below_sum_of_iis() {
        let net = cnv(CnvVariant::W1A1);
        let r = simulate_network(&net, 10, 8);
        let sum_ii: u64 = net.stages.iter().map(|s| s.cycles_per_frame()).sum();
        assert!(r.first_out_cycles <= sum_ii * 2);
        assert!(r.first_out_cycles > net.initiation_interval());
    }

    #[test]
    fn throughput_scales_with_frame_count() {
        // steady state: doubling frames should not halve frames/kcycle
        let net = cnv(CnvVariant::W1A1);
        let a = simulate_network(&net, 20, 8).frames_per_kcycle;
        let b = simulate_network(&net, 40, 8).frames_per_kcycle;
        assert!((b / a - 1.0).abs() < 0.2, "a={a} b={b}");
    }

    #[test]
    fn queue_hwm_bounded_by_capacity() {
        let net = resnet50(1);
        let r = simulate_network(&net, 15, 6);
        for (name, hwm) in &r.queue_hwm {
            let cap = if name.ends_with("_join") { 6 } else { 2 };
            assert!(*hwm <= cap, "{name}: hwm {hwm} > cap {cap}");
        }
    }

    #[test]
    fn single_stage_network_degenerate() {
        let mut net = cnv(CnvVariant::W1A1);
        net.stages.truncate(1);
        let r = simulate_network(&net, 5, 4);
        assert!(r.vs_analytic > 0.9);
    }

    #[test]
    fn chain_steady_rate_hits_the_bottleneck_exactly() {
        // a chain with one dominant server: the second-half window sees
        // outputs spaced exactly by the bottleneck service time
        let chain: Vec<ChainStage> = [50u64, 200, 70, 30]
            .iter()
            .enumerate()
            .map(|(i, &s)| ChainStage { name: format!("s{i}"), service: s, queue_cap: 2 })
            .collect();
        let r = simulate_chain(&chain, 100);
        let rate = r.steady_rate();
        assert!(
            (rate - 1.0 / 200.0).abs() / (1.0 / 200.0) < 0.005,
            "rate {rate} vs 1/200"
        );
        assert_eq!(r.out_times.len(), 100);
        assert!(r.out_times.windows(2).all(|w| w[1] > w[0]));
    }

    #[test]
    fn equal_service_chain_stays_lockstep() {
        // adjacent equal-II servers must not introduce bubbles
        let chain: Vec<ChainStage> = (0..5)
            .map(|i| ChainStage { name: format!("s{i}"), service: 100, queue_cap: 2 })
            .collect();
        let r = simulate_chain(&chain, 80);
        let rate = r.steady_rate();
        assert!((rate - 0.01).abs() / 0.01 < 0.005, "rate {rate}");
    }

    #[test]
    fn sharded_sim_matches_plan_within_one_percent() {
        let net = cnv(CnvVariant::W2A2);
        let devs = [crate::device::zynq_7012s(), crate::device::zynq_7012s()];
        let cfg = crate::sharding::PartitionConfig {
            generations: 0,
            ..crate::sharding::PartitionConfig::default()
        };
        let plan = crate::sharding::partition(&net, &devs, cfg).unwrap();
        let r = simulate_sharded(&net, &plan, 300, 8);
        assert!(
            (r.vs_analytic - 1.0).abs() <= 0.01,
            "sharded sim {} of analytic (fps {} vs {})",
            r.vs_analytic,
            r.fps,
            plan.fps
        );
        // the chain includes a link stage and reports its queue
        assert!(r.queue_hwm.iter().any(|(n, _)| n.starts_with("link")));
        assert!(r.first_out_ns > 0);
    }

    #[test]
    fn starved_link_fifo_throttles_the_sharded_pipeline() {
        // with a frames-deep bypass... a link FIFO of 1 still sustains the
        // bottleneck for a serial chain; the guard here is that the knob
        // plumbs through and the hwm respects the bound
        let net = cnv(CnvVariant::W2A2);
        let devs = [crate::device::zynq_7012s(), crate::device::zynq_7012s()];
        let cfg = crate::sharding::PartitionConfig {
            generations: 0,
            ..crate::sharding::PartitionConfig::default()
        };
        let plan = crate::sharding::partition(&net, &devs, cfg).unwrap();
        let r = simulate_sharded(&net, &plan, 120, 3);
        for (name, hwm) in &r.queue_hwm {
            if name.starts_with("link") {
                assert!(*hwm <= 3, "{name}: hwm {hwm} exceeds link FIFO bound");
            }
        }
    }
}
