//! Whole-network streaming pipeline simulator.
//!
//! Discrete-time, frame-granular with fractional progress: every stage is a
//! server with service time = its initiation interval (cycles/frame);
//! stages are connected by bounded FIFOs (frames); resblocks fork into a
//! branch chain and a bypass FIFO that re-join (§III.B). The simulator
//! validates the analytic model (steady-state FPS = F_c / max II) and
//! exposes what the analytic model cannot: warm-up transients, FIFO
//! occupancy high-water marks (FIFO sizing), and the slowdown from
//! under-provisioned bypass FIFOs.

use crate::nn::{Network, Stage};

/// One simulated pipeline stage.
#[derive(Clone, Debug)]
struct SimStage {
    name: String,
    /// Service time in compute cycles per frame.
    ii: u64,
    /// Completion time of the frame currently in service (None = idle).
    busy_until: Option<u64>,
    /// Frames waiting at the input.
    queue: u64,
    queue_cap: u64,
    /// High-water mark of the input queue.
    hwm: u64,
    /// Frames completed.
    done: u64,
}

impl SimStage {
    fn new(name: String, ii: u64, queue_cap: u64) -> SimStage {
        SimStage { name, ii: ii.max(1), busy_until: None, queue: 0, queue_cap, hwm: 0, done: 0 }
    }

    fn can_accept(&self) -> bool {
        self.queue < self.queue_cap
    }

    fn push(&mut self, _t: u64) {
        self.queue += 1;
        self.hwm = self.hwm.max(self.queue);
    }
}

/// Simulation result.
#[derive(Clone, Debug)]
pub struct PipelineResult {
    /// Steady-state throughput in frames per kilocycle.
    pub frames_per_kcycle: f64,
    /// Cycles from first injection to first output (fill latency).
    pub first_out_cycles: u64,
    /// Total cycles to drain all frames.
    pub total_cycles: u64,
    /// Per-stage input-queue high-water marks.
    pub queue_hwm: Vec<(String, u64)>,
    /// Throughput relative to the analytic bound (1.0 = matches).
    pub vs_analytic: f64,
}

/// Flatten the network into a serial chain (resblock branches are serial in
/// time — the bypass FIFO is modelled by a larger queue at the join).
fn flatten(net: &Network, bypass_cap: u64) -> Vec<SimStage> {
    let mut out = Vec::new();
    for s in &net.stages {
        match s {
            Stage::Mvau(l) => {
                out.push(SimStage::new(l.name.clone(), l.cycles_per_frame(), 2))
            }
            Stage::MaxPool { name, .. } => out.push(SimStage::new(name.clone(), 1, 2)),
            Stage::ResBlock { name, branch, .. } => {
                for l in branch {
                    out.push(SimStage::new(l.name.clone(), l.cycles_per_frame(), 2));
                }
                // the join: service = instantaneous, but its queue models
                // the bypass FIFO capacity
                out.push(SimStage::new(format!("{name}_join"), 1, bypass_cap));
            }
        }
    }
    out
}

/// Run `frames` frames through the network; `bypass_cap` is the per-join
/// bypass FIFO capacity in frames (the paper's deep-FIFO knob).
pub fn simulate_network(net: &Network, frames: u64, bypass_cap: u64) -> PipelineResult {
    let mut stages = flatten(net, bypass_cap);
    let n = stages.len();
    assert!(n > 0 && frames > 0);
    let max_ii = stages.iter().map(|s| s.ii).max().unwrap();

    // event-driven over completion times, advancing in II-sized hops
    let mut t: u64 = 0;
    let mut injected = 0u64;
    let mut first_out = None;
    let mut last_out = 0u64;
    let horizon = frames * max_ii * 4 + stages.iter().map(|s| s.ii).sum::<u64>() * 2;

    while stages[n - 1].done < frames && t < horizon {
        // 1. retire completions back-to-front, push downstream if space
        for i in (0..n).rev() {
            if let Some(done_at) = stages[i].busy_until {
                if done_at <= t {
                    let can = i + 1 >= n || stages[i + 1].can_accept();
                    if can {
                        stages[i].busy_until = None;
                        stages[i].done += 1;
                        if i + 1 < n {
                            stages[i + 1].push(t);
                        } else {
                            if first_out.is_none() {
                                first_out = Some(t);
                            }
                            last_out = t;
                        }
                    }
                }
            }
        }
        // 2. start service where idle and queued
        for i in 0..n {
            if stages[i].busy_until.is_none() && stages[i].queue > 0 {
                stages[i].queue -= 1;
                let ii = stages[i].ii;
                stages[i].busy_until = Some(t + ii);
            }
        }
        // 3. inject at the source
        if injected < frames && stages[0].can_accept() {
            stages[0].push(t);
            injected += 1;
        }
        // 4. advance to the next interesting time
        let next = stages
            .iter()
            .filter_map(|s| s.busy_until)
            .filter(|&d| d > t)
            .min()
            .unwrap_or(t + 1);
        t = next.max(t + 1);
    }

    let total = t;
    // steady-state throughput: measured between the first and last output
    // so the pipeline-fill transient does not dilute it
    let first = first_out.unwrap_or(0);
    let steady_cycles = last_out.saturating_sub(first).max(1);
    let fpk = if frames > 1 {
        (frames - 1) as f64 / (steady_cycles as f64 / 1000.0)
    } else {
        frames as f64 / (total as f64 / 1000.0)
    };
    let analytic_fpk = 1000.0 / max_ii as f64;
    PipelineResult {
        frames_per_kcycle: fpk,
        first_out_cycles: first_out.unwrap_or(total),
        total_cycles: total,
        queue_hwm: stages.iter().map(|s| (s.name.clone(), s.hwm)).collect(),
        vs_analytic: fpk / analytic_fpk,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::{cnv, resnet50, CnvVariant};

    #[test]
    fn cnv_pipeline_matches_analytic_ii() {
        let net = cnv(CnvVariant::W1A1);
        let r = simulate_network(&net, 40, 8);
        assert!(
            (0.9..=1.02).contains(&r.vs_analytic),
            "throughput {} of analytic",
            r.vs_analytic
        );
    }

    #[test]
    fn rn50_pipeline_matches_analytic_ii() {
        let net = resnet50(1);
        let r = simulate_network(&net, 25, 8);
        assert!(
            (0.85..=1.02).contains(&r.vs_analytic),
            "throughput {} of analytic",
            r.vs_analytic
        );
    }

    #[test]
    fn fill_latency_below_sum_of_iis() {
        let net = cnv(CnvVariant::W1A1);
        let r = simulate_network(&net, 10, 8);
        let sum_ii: u64 = net.stages.iter().map(|s| s.cycles_per_frame()).sum();
        assert!(r.first_out_cycles <= sum_ii * 2);
        assert!(r.first_out_cycles > net.initiation_interval());
    }

    #[test]
    fn throughput_scales_with_frame_count() {
        // steady state: doubling frames should not halve frames/kcycle
        let net = cnv(CnvVariant::W1A1);
        let a = simulate_network(&net, 20, 8).frames_per_kcycle;
        let b = simulate_network(&net, 40, 8).frames_per_kcycle;
        assert!((b / a - 1.0).abs() < 0.2, "a={a} b={b}");
    }

    #[test]
    fn queue_hwm_bounded_by_capacity() {
        let net = resnet50(1);
        let r = simulate_network(&net, 15, 6);
        for (name, hwm) in &r.queue_hwm {
            let cap = if name.ends_with("_join") { 6 } else { 2 };
            assert!(*hwm <= cap, "{name}: hwm {hwm} > cap {cap}");
        }
    }

    #[test]
    fn single_stage_network_degenerate() {
        let mut net = cnv(CnvVariant::W1A1);
        net.stages.truncate(1);
        let r = simulate_network(&net, 5, 4);
        assert!(r.vs_analytic > 0.9);
    }
}
