//! Deterministic discrete-event queue: the clock core of the fleet
//! simulator ([`crate::sim::fleet`]).
//!
//! Events are ordered by `(time_ns, seq)` — virtual nanoseconds first,
//! then a monotone submission sequence number breaking same-instant ties
//! FIFO. The tie-break is what makes the simulator bit-deterministic:
//! two events scheduled for the same instant always pop in the order
//! they were scheduled, independent of heap internals, platform, or how
//! many OS threads the test harness runs. [`EventQueue::pop`] asserts
//! that popped timestamps never go backwards — the no-event-processed-
//! out-of-order invariant the fuzz suite leans on.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// One scheduled entry. Ordering ignores the payload entirely: the heap
/// is keyed on `(time_ns, seq)` alone, so the payload type needs no
/// `Ord`.
struct Entry<E> {
    time_ns: u64,
    seq: u64,
    ev: E,
}

impl<E> PartialEq for Entry<E> {
    fn eq(&self, other: &Self) -> bool {
        self.time_ns == other.time_ns && self.seq == other.seq
    }
}

impl<E> Eq for Entry<E> {}

impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl<E> Ord for Entry<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap: reverse so the earliest (time, seq)
        // pops first
        (other.time_ns, other.seq).cmp(&(self.time_ns, self.seq))
    }
}

/// A deterministic min-queue of timestamped events.
///
/// `schedule` may insert at any (non-past-relative-to-pop) time;
/// same-time events pop in scheduling order. The queue tracks the last
/// popped timestamp and panics if time would run backwards — a
/// scheduling bug in the driver, never a recoverable condition.
pub struct EventQueue<E> {
    heap: BinaryHeap<Entry<E>>,
    next_seq: u64,
    last_popped: u64,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        EventQueue::new()
    }
}

impl<E> EventQueue<E> {
    /// An empty queue at virtual time zero.
    pub fn new() -> EventQueue<E> {
        EventQueue { heap: BinaryHeap::new(), next_seq: 0, last_popped: 0 }
    }

    /// Schedule `ev` at `time_ns` and return its sequence number (the
    /// FIFO tie-break key; also usable as a stable event identity).
    pub fn schedule(&mut self, time_ns: u64, ev: E) -> u64 {
        let seq = self.next_seq;
        self.next_seq += 1;
        self.heap.push(Entry { time_ns, seq, ev });
        seq
    }

    /// Pop the earliest event as `(time_ns, seq, event)`.
    ///
    /// # Panics
    /// If the popped timestamp precedes the previously popped one (the
    /// driver scheduled an event in the past).
    pub fn pop(&mut self) -> Option<(u64, u64, E)> {
        let e = self.heap.pop()?;
        assert!(
            e.time_ns >= self.last_popped,
            "event time ran backwards: {} after {}",
            e.time_ns,
            self.last_popped
        );
        self.last_popped = e.time_ns;
        Some((e.time_ns, e.seq, e.ev))
    }

    /// Timestamp of the next event, if any.
    pub fn peek_time(&self) -> Option<u64> {
        self.heap.peek().map(|e| e.time_ns)
    }

    /// Events currently scheduled.
    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// True when no events are scheduled.
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.schedule(30, "c");
        q.schedule(10, "a");
        q.schedule(20, "b");
        let order: Vec<&str> = std::iter::from_fn(|| q.pop().map(|(_, _, e)| e)).collect();
        assert_eq!(order, vec!["a", "b", "c"]);
    }

    #[test]
    fn same_instant_ties_break_fifo() {
        let mut q = EventQueue::new();
        for i in 0..100 {
            q.schedule(5, i);
        }
        let order: Vec<u64> = std::iter::from_fn(|| q.pop().map(|(_, _, e)| e)).collect();
        assert_eq!(order, (0..100).collect::<Vec<u64>>());
    }

    #[test]
    fn interleaved_schedules_stay_monotone() {
        let mut q = EventQueue::new();
        q.schedule(10, 0u64);
        let (t, _, _) = q.pop().unwrap();
        assert_eq!(t, 10);
        // scheduling at the current time is fine; popping stays monotone
        q.schedule(10, 1);
        q.schedule(15, 2);
        assert_eq!(q.peek_time(), Some(10));
        assert_eq!(q.pop().unwrap().0, 10);
        assert_eq!(q.pop().unwrap().0, 15);
        assert!(q.is_empty());
    }

    #[test]
    #[should_panic(expected = "ran backwards")]
    fn scheduling_in_the_past_is_caught_at_pop() {
        let mut q = EventQueue::new();
        q.schedule(100, ());
        q.pop();
        q.schedule(50, ());
        q.pop();
    }
}
