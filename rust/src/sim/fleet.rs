//! Deterministic discrete-event simulator for an entire [`Deployment`].
//!
//! The thread-backed [`crate::coordinator::Server`] runs every stage of
//! every chain group as a real OS thread, which caps experiments at tens
//! of workers and seconds of simulated time. `FleetSim` executes the
//! *same* fleet semantics — ordered chain groups, per-stage bounded
//! queues, size-or-deadline batchers, per-worker in-flight windows,
//! RR/JSQ/SWRR admission via the shared
//! [`crate::coordinator::dispatch`] seam, store-and-forward or
//! overlapped micro-batch stage links, and the control plane's
//! [`SignalTap`]/[`Autoscaler`]/[`SloController`] on simulated ticks —
//! as a single-threaded event loop over a virtual nanosecond clock
//! ([`crate::sim::event::EventQueue`]). A thousand chain groups over a
//! million requests simulate in wall-clock seconds, and the run is
//! bit-deterministic: same seed + trace ⇒ identical event order,
//! [`FleetSummary`] and [`ControlEvent`] journal, regardless of host
//! load or test-harness threading.
//!
//! ## Clock model
//!
//! Virtual time is `u64` nanoseconds. Three event kinds drive the loop:
//! trace **arrivals** (admission + synthetic input draw, mirroring
//! `Server::replay`), worker **wakes** (batch deadline expiry, transfer
//! completion, batch ready — the worker state machine re-evaluates
//! idempotently at each wake), and control **ticks** (signal window
//! close + autoscale/SLO actuation, mirroring `control::run_loop`'s
//! arrival/drain/trailing phases). Same-instant events process in
//! scheduling order, which is itself deterministic.
//!
//! ## Sharing seam with the thread-backed coordinator
//!
//! Nothing policy-shaped is duplicated: group choice and fallback order
//! come from [`crate::coordinator::dispatch`] (the router's own hot
//! path), batching settings are [`BatcherConfig`] snapshots with the
//! same µs truncation as `SharedBatcher`, metrics flow through the real
//! [`FleetMetrics`] (with a virtual-time span override), and the
//! control loop drives the real [`SignalTap`], [`Autoscaler`] and
//! [`SloController`] — so a controller change is exercised identically
//! by both backends. `tests/fleet_sim.rs` keeps the two backends honest
//! with differential runs on small fleets.

use std::collections::VecDeque;
use std::sync::Arc;
use std::time::Duration;

use crate::control::{
    Autoscaler, AutoscalerConfig, ControlEvent, ControlEventKind, ScaleDecision, SignalConfig,
    SignalCtx, SignalTap, SloConfig, SloController,
};
use crate::coordinator::dispatch::{deadline_feasible, fallback_order, preferred_group};
use crate::coordinator::{
    chain_fps, BatcherConfig, Completion, Deployment, FleetMetrics, FleetSummary, Policy,
    Scheduler, Trace,
};
use crate::obs::{
    Exposition, HealthConfig, HealthJournal, HealthMonitor, Obs, ObsConfig, RequestSpan,
    SpanEvent, SpanRing, VirtualClock,
};
use crate::sim::event::EventQueue;
use crate::util::rng::Rng;

/// Service-time model for one simulated worker, mirroring the two mock
/// backends the thread-backed server tests with.
#[derive(Clone, Copy, Debug)]
pub enum SimBackend {
    /// Store-and-forward: the worker blocks for the whole batch service
    /// (`base + per_item · k`), exactly like
    /// [`crate::coordinator::MockBackend`] — the in-flight window never
    /// fills because the worker is busy until the batch is done.
    Mock {
        /// Fixed per-batch overhead.
        base: Duration,
        /// Marginal service time per batched frame.
        per_item: Duration,
    },
    /// Overlapped micro-batch transfer: the worker is only occupied for
    /// the transfer (`xfer_per_item · k`), then the batch computes on a
    /// serial device queue (`compute_per_item · k` after the device
    /// frees), exactly like
    /// [`crate::coordinator::PipelinedMockBackend`] — up to
    /// [`Deployment::window`] batches overlap transfer with compute.
    Pipelined {
        /// Per-frame host→device transfer time (occupies the worker).
        xfer_per_item: Duration,
        /// Per-frame device compute time (overlaps the next transfer).
        compute_per_item: Duration,
    },
}

impl SimBackend {
    /// Effective per-frame service interval — the analytic capacity
    /// figure used for SWRR weights, SLO chain co-tuning and
    /// slowest-first scale-in ranking.
    pub fn service_per_item(&self) -> Duration {
        match *self {
            SimBackend::Mock { per_item, .. } => per_item,
            SimBackend::Pipelined { xfer_per_item, compute_per_item } => {
                xfer_per_item.max(compute_per_item)
            }
        }
    }
}

/// Virtual-tick control plane for a simulated fleet, mirroring
/// [`crate::control::LoopConfig`]'s knobs.
#[derive(Clone, Debug)]
pub struct SimControl {
    /// Virtual control-tick period.
    pub tick: Duration,
    /// Signal-window configuration for the [`SignalTap`].
    pub signal: SignalConfig,
    /// Whole-group autoscaler; `None` disables scaling.
    pub autoscaler: Option<AutoscalerConfig>,
    /// SLO batching-window controller; `None` disables retuning.
    pub slo: Option<SloConfig>,
    /// Idle ticks appended after the fleet drains (the thread loop's
    /// trailing scale-in observation window).
    pub trailing_ticks: usize,
}

impl Default for SimControl {
    fn default() -> SimControl {
        SimControl {
            tick: Duration::from_millis(25),
            signal: SignalConfig::default(),
            autoscaler: None,
            slo: None,
            trailing_ticks: 8,
        }
    }
}

/// Simulator run configuration.
#[derive(Clone, Debug)]
pub struct SimConfig {
    /// Synthetic input length per request (mirrors `Server::replay`'s
    /// `input_len`: the RNG draws `input_len` bytes per arrival).
    pub input_len: usize,
    /// Seed for the synthetic-input stream.
    pub seed: u64,
    /// Control plane on virtual ticks; `None` runs open-loop.
    pub control: Option<SimControl>,
    /// Span tracing: the same head-based sampler and flight recorder
    /// the threaded server uses, stamping through a [`VirtualClock`]
    /// the event loop publishes before every handler — so trace files
    /// from both drivers are directly comparable.
    pub obs: ObsConfig,
    /// Long-horizon health collection (downsampled series + burn-rate
    /// alerts), observed on control ticks in virtual time; `None`
    /// disables it.
    pub health: Option<HealthConfig>,
}

impl Default for SimConfig {
    fn default() -> SimConfig {
        SimConfig {
            input_len: 8,
            seed: 2020,
            control: None,
            obs: ObsConfig::default(),
            health: None,
        }
    }
}

/// Result of a simulated fleet run: the same [`FleetSummary`] and
/// [`ControlEvent`] journal shapes the thread-backed server emits, plus
/// simulator-side counters the fuzz/determinism suites assert on.
#[derive(Debug)]
pub struct SimReport {
    /// Fleet/group/stage latency + throughput summary (virtual-time
    /// span). Groups are indexed by *backend slot* (standby slots
    /// included), so rows stay stable across scale events.
    pub summary: FleetSummary,
    /// Journal of autoscale/SLO actuations, in tick order.
    pub events: Vec<ControlEvent>,
    /// Control ticks executed.
    pub ticks: usize,
    /// Routable chain groups at t = 0.
    pub initial_groups: usize,
    /// Routable chain groups when the run ended.
    pub final_groups: usize,
    /// High-water mark of routable chain groups.
    pub max_groups_seen: usize,
    /// Requests accepted by admission control.
    pub submitted: usize,
    /// Requests shed (every routable entry queue full).
    pub shed: usize,
    /// Requests completed (must equal `submitted` at end of run).
    pub completed: usize,
    /// Virtual seconds elapsed at the last event.
    pub sim_seconds: f64,
    /// Events processed by the loop.
    pub events_processed: u64,
    /// Requests shed up front by the tenant deadline-feasibility rule
    /// (disjoint from `shed`, which counts queue-full rejections).
    pub deadline_shed: usize,
    /// Health journal (downsampled cells + alert transitions) when
    /// [`SimConfig::health`] was set; `None` otherwise.
    pub health: Option<HealthJournal>,
    /// FNV-1a hash over the processed `(time, seq, kind)` stream — a
    /// fingerprint of the exact event ordering for determinism tests.
    pub order_hash: u64,
    /// High-water mark of any stage's bounded-queue occupancy.
    pub max_queue_seen: usize,
}

/// One request in flight through the simulated fleet. Only the payload
/// *sum* is carried: every simulated stage computes `[Σ inputs, k]` like
/// the mock backends, so the scalar is enough to reproduce outputs.
#[derive(Debug)]
struct SimReq {
    id: u64,
    sum: f32,
    arrival: u64,
    stage_arrival: u64,
    stage_latencies: Vec<Duration>,
    stage_batches: Vec<usize>,
    /// Flight-recorder span; `None` for the unsampled majority.
    span: Option<Box<RequestSpan>>,
}

/// A submitted batch waiting on its virtual completion time.
struct Flight {
    ready_at: u64,
    reqs: Vec<SimReq>,
}

/// An open batch-formation window (the batcher's gather phase).
struct Gather {
    reqs: Vec<SimReq>,
    /// Closes at this time even if under-full (`max_wait` expiry).
    deadline: u64,
    /// `max_batch` snapshot taken when the gather opened (live retunes
    /// apply from the *next* batch, like `SharedBatcher`).
    cap: usize,
}

/// One simulated stage worker: bounded entry queue, batcher, in-flight
/// window and the store-and-forward / overlapped service model.
struct SimWorker {
    backend: SimBackend,
    cfg: BatcherConfig,
    queue: VecDeque<SimReq>,
    gather: Option<Gather>,
    in_flight: VecDeque<Flight>,
    busy_until: u64,
    device_free: u64,
    /// Queued + gathering + executing + forwarded-but-unacked frames —
    /// the JSQ load signal, mirroring the router's `stage_outstanding`.
    outstanding: usize,
    /// Frames that completed here but found the downstream queue full:
    /// the upstream worker stalls (the thread worker blocks in `send`)
    /// until the downstream stage drains.
    blocked: VecDeque<SimReq>,
}

impl SimWorker {
    fn new(backend: SimBackend, cfg: BatcherConfig) -> SimWorker {
        SimWorker {
            backend,
            cfg: truncate_cfg(cfg),
            queue: VecDeque::new(),
            gather: None,
            in_flight: VecDeque::new(),
            busy_until: 0,
            device_free: 0,
            outstanding: 0,
            blocked: VecDeque::new(),
        }
    }
}

/// One simulated chain group (a backend slot — it keeps its identity and
/// metrics row whether routable or standby).
struct SimGroup {
    workers: Vec<SimWorker>,
    /// Per-stage service interval (for SWRR weights / SLO co-tuning).
    service: Vec<Duration>,
    /// Analytic chain capacity (slowest-first scale-in, fastest-first
    /// scale-out).
    fps: f64,
    /// MIMD state for chain SLO co-tuning (mirrors `run_loop`'s
    /// `slo_base`).
    slo_base: BatcherConfig,
}

enum Ev {
    /// Trace arrival `idx` reaches admission control.
    Arrival(usize),
    /// Re-evaluate worker `(group, stage)` — deadline, transfer done, or
    /// batch ready.
    Wake(usize, usize),
    /// Control tick: close the signal window, maybe actuate.
    Tick,
}

fn ns(d: Duration) -> u64 {
    u64::try_from(d.as_nanos()).unwrap_or(u64::MAX)
}

fn secs(t: u64) -> f64 {
    t as f64 / 1e9
}

/// Mirror `SharedBatcher`'s packed representation: waits are stored at
/// µs granularity and batch sizes clamp to 1..=65535, so the simulated
/// worker sees exactly what a thread worker would read back.
fn truncate_cfg(cfg: BatcherConfig) -> BatcherConfig {
    BatcherConfig {
        max_batch: cfg.max_batch.clamp(1, 65_535),
        max_wait: Duration::from_micros(cfg.max_wait.as_micros().min(u64::MAX as u128) as u64),
    }
}

/// Discrete-event executor for a [`Deployment`]. Build with
/// [`FleetSim::new`] (per-slot backends, extra slots = autoscale
/// standby) or [`FleetSim::uniform`], then [`FleetSim::run`] a trace.
pub struct FleetSim {
    groups: Vec<SimGroup>,
    /// Routable group slots, in router order.
    active: Vec<usize>,
    /// Slots available to scale out into.
    standby: Vec<usize>,
    policy: Policy,
    scheduler: Scheduler,
    queue_depth: usize,
    window: usize,
    cfg: SimConfig,

    /// Per-slot tenant ids from the plan (standby slots join tenant 0).
    slot_tenants: Vec<usize>,
    /// Per-tenant completion budgets (index = tenant id, `None` =
    /// best-effort), mirroring [`crate::coordinator::Server::set_tenancy`].
    tenant_budgets: Vec<Option<Duration>>,
    /// Per-slot estimated per-request service time (ns) feeding the
    /// admission deadline rule; zero = shed only already-expired.
    est_service_ns: Vec<u64>,
    /// Per-tenant routable member slots, router order — rebuilt on every
    /// scale event, like the threaded router's tenant tables.
    tenant_members: Vec<Vec<usize>>,
    tenant_schedulers: Vec<Scheduler>,
    /// Tenant routing active (tagged run or `set_tenancy` called).
    tenancy: bool,
    /// Per-arrival tenant tags for the current run (empty = all tenant 0).
    tags: Vec<usize>,
    deadline_shed: usize,

    q: EventQueue<Ev>,
    now: u64,
    rng: Rng,
    trace: Vec<u64>,
    arrivals_done: bool,

    /// Published before every event handler; span stamps read it.
    clock: Arc<VirtualClock>,
    obs: Arc<Obs>,
    /// One span ring per worker, `rings[slot][stage]` — pre-registered
    /// for every slot (standby included) so scale-out never allocates.
    rings: Vec<Vec<Arc<SpanRing>>>,
    exposition: Option<Exposition>,
    health: Option<HealthMonitor>,

    fm: FleetMetrics,
    tap: SignalTap,
    scaler: Option<Autoscaler>,
    slo: Option<SloController>,
    events: Vec<ControlEvent>,
    trailing_left: usize,
    tick_ns: u64,

    initial_groups: usize,
    accepted: usize,
    shed: usize,
    completed: usize,
    done: Vec<bool>,
    last_completion: u64,
    max_groups_seen: usize,
    max_queue_seen: usize,
    events_processed: u64,
    order_hash: u64,
}

impl FleetSim {
    /// Build a simulator for `plan` with one [`SimBackend`] per worker:
    /// `backends[g][s]` serves stage `s` of group slot `g`. Slots beyond
    /// `plan.groups.len()` are standby capacity the autoscaler can scale
    /// out into (they take the plan's default batcher). Panics if the
    /// initial slots don't match the plan's stage counts.
    pub fn new(plan: Deployment, backends: Vec<Vec<SimBackend>>, cfg: SimConfig) -> FleetSim {
        let plan = plan.normalized();
        assert!(
            backends.len() >= plan.groups.len(),
            "need at least one backend slot per plan group"
        );
        let mut groups = Vec::with_capacity(backends.len());
        for (g, stages) in backends.iter().enumerate() {
            assert!(!stages.is_empty(), "backend slot {g} has no stages");
            let batcher = if g < plan.groups.len() {
                assert_eq!(
                    stages.len(),
                    plan.groups[g].stages,
                    "backend slot {g} stage count != plan"
                );
                plan.group_batcher(g)
            } else {
                plan.batcher
            };
            let workers: Vec<SimWorker> =
                stages.iter().map(|&b| SimWorker::new(b, batcher)).collect();
            let service: Vec<Duration> = stages.iter().map(|b| b.service_per_item()).collect();
            let fps = chain_fps(&service);
            groups.push(SimGroup { workers, service, fps, slo_base: truncate_cfg(batcher) });
        }
        let active: Vec<usize> = (0..plan.groups.len()).collect();
        let standby: Vec<usize> = (plan.groups.len()..groups.len()).collect();
        let mut slot_tenants: Vec<usize> =
            (0..plan.groups.len()).map(|g| plan.tenant_of(g)).collect();
        slot_tenants.resize(groups.len(), 0);
        let shape: Vec<usize> = groups.iter().map(|g| g.workers.len()).collect();
        let scheduler = Self::build_scheduler(&plan.policy, &groups, &active);
        let (tap, scaler, slo, trailing, tick_ns) = match &cfg.control {
            Some(c) => (
                SignalTap::new(c.signal),
                c.autoscaler.map(Autoscaler::new),
                c.slo.map(SloController::new),
                c.trailing_ticks,
                ns(c.tick).max(1),
            ),
            None => (SignalTap::new(SignalConfig::default()), None, None, 0, 0),
        };
        let initial = active.len();
        let health = cfg.health.clone().map(HealthMonitor::new);
        // health collection rides the tick cadence; without a control
        // plane (static/baseline arms) ticks still run, paced by the
        // health sample interval, so the monitor sees mid-run snapshots
        let tick_ns = if tick_ns == 0 && cfg.health.is_some() {
            let sample_s = cfg.health.as_ref().map_or(1.0, |h| h.sample_s);
            ((sample_s.max(1e-3)) * 1e9) as u64
        } else {
            tick_ns
        };
        let clock = Arc::new(VirtualClock::new());
        let obs = Obs::new(&cfg.obs, Arc::clone(&clock) as Arc<dyn crate::obs::Clock>);
        let rings: Vec<Vec<Arc<SpanRing>>> = groups
            .iter()
            .map(|g| g.workers.iter().map(|_| obs.recorder().register()).collect())
            .collect();
        let est_service_ns = vec![0; groups.len()];
        FleetSim {
            queue_depth: plan.queue_depth,
            window: plan.window,
            policy: plan.policy.clone(),
            scheduler,
            slot_tenants,
            tenant_budgets: Vec::new(),
            est_service_ns,
            tenant_members: Vec::new(),
            tenant_schedulers: Vec::new(),
            tenancy: false,
            tags: Vec::new(),
            deadline_shed: 0,
            groups,
            active,
            standby,
            rng: Rng::new(cfg.seed),
            cfg,
            q: EventQueue::new(),
            now: 0,
            trace: Vec::new(),
            arrivals_done: false,
            clock,
            obs,
            rings,
            exposition: None,
            health,
            fm: FleetMetrics::new(&shape),
            tap,
            scaler,
            slo,
            events: Vec::new(),
            trailing_left: trailing,
            tick_ns,
            initial_groups: initial,
            accepted: 0,
            shed: 0,
            completed: 0,
            done: Vec::new(),
            last_completion: 0,
            max_groups_seen: initial,
            max_queue_seen: 0,
            events_processed: 0,
            order_hash: 0xcbf2_9ce4_8422_2325, // FNV-1a offset basis
        }
    }

    /// Build a simulator serving every worker with the same backend.
    pub fn uniform(plan: Deployment, backend: SimBackend, cfg: SimConfig) -> FleetSim {
        let plan = plan.normalized();
        let backends = plan.group_sizes().iter().map(|&k| vec![backend; k]).collect();
        FleetSim::new(plan, backends, cfg)
    }

    /// Build a simulator with `standby` extra single-profile group slots
    /// beyond the plan (each shaped like the plan's first group) for the
    /// autoscaler to grow into.
    pub fn uniform_with_standby(
        plan: Deployment,
        backend: SimBackend,
        standby: usize,
        cfg: SimConfig,
    ) -> FleetSim {
        let plan = plan.normalized();
        let stages0 = plan.groups[0].stages;
        let mut backends: Vec<Vec<SimBackend>> =
            plan.group_sizes().iter().map(|&k| vec![backend; k]).collect();
        for _ in 0..standby {
            backends.push(vec![backend; stages0]);
        }
        FleetSim::new(plan, backends, cfg)
    }

    /// The observability hub this simulator stamps through (virtual
    /// clock, sampler, span pool, flight recorder).
    pub fn obs(&self) -> &Arc<Obs> {
        &self.obs
    }

    /// Attach a live metrics emitter. It is driven on virtual control
    /// ticks (so it needs [`SimConfig::control`] to emit mid-run) and
    /// always emits a final snapshot when the run drains.
    pub fn set_exposition(&mut self, e: Exposition) {
        self.exposition = Some(e);
    }

    /// Mirror of [`crate::coordinator::Server::set_tenancy`]: install
    /// per-tenant completion budgets (index = tenant id; `None` =
    /// best-effort) and a per-slot estimated service time driving the
    /// [`deadline_feasible`] admission rule. Missing slots estimate
    /// zero, which sheds only requests whose deadline already passed.
    pub fn set_tenancy(&mut self, budgets: Vec<Option<Duration>>, est_service: Vec<Duration>) {
        self.tenant_budgets = budgets;
        self.est_service_ns = est_service.iter().map(|&d| ns(d)).collect();
        self.est_service_ns.resize(self.groups.len(), 0);
        self.tenancy = true;
        self.rebuild_tenant_state();
    }

    /// Recompute per-tenant member lists and schedulers over the
    /// routable set — the simulated analogue of the threaded router's
    /// tenant-table rebuild. Tenants with no routable group keep an
    /// empty member list and shed every arrival.
    fn rebuild_tenant_state(&mut self) {
        let n_tenants = self
            .active
            .iter()
            .map(|&gi| self.slot_tenants[gi] + 1)
            .max()
            .unwrap_or(1)
            .max(self.tenant_budgets.len());
        let mut members = vec![Vec::new(); n_tenants];
        for &gi in &self.active {
            members[self.slot_tenants[gi]].push(gi);
        }
        self.tenant_schedulers = members
            .iter()
            .map(|m| Scheduler::new(self.policy.clone(), m.len().max(1)))
            .collect();
        self.tenant_members = members;
    }

    fn build_scheduler(policy: &Policy, groups: &[SimGroup], active: &[usize]) -> Scheduler {
        let policy = match policy {
            Policy::Weighted(_) => {
                Policy::Weighted(active.iter().map(|&gi| groups[gi].fps.max(1e-6)).collect())
            }
            p => p.clone(),
        };
        Scheduler::new(policy, active.len().max(1))
    }

    /// Run the simulator over `trace`, consuming it like
    /// `Server::replay`: one synthetic request per arrival, admission
    /// through the shared dispatch seam, then drain (control ticks keep
    /// firing) plus the configured trailing ticks.
    pub fn run(self, trace: &Trace) -> SimReport {
        self.run_tagged(trace, &[])
    }

    /// Run like [`FleetSim::run`], with `tags[i]` naming the tenant of
    /// arrival `i` (missing tags default to tenant 0). A tagged run —
    /// or any run after [`FleetSim::set_tenancy`] — routes each arrival
    /// only to its tenant's groups, applies the deadline-feasibility
    /// shed rule, and splits [`FleetMetrics`] per tenant, mirroring
    /// `Server::replay_tagged`.
    pub fn run_tagged(mut self, trace: &Trace, tags: &[usize]) -> SimReport {
        if !tags.is_empty() {
            self.tenancy = true;
        }
        if self.tenancy {
            self.tags = tags.to_vec();
            self.rebuild_tenant_state();
            self.fm.set_tenants(self.slot_tenants.clone());
            self.fm.set_tenant_slos_ms(
                self.tenant_budgets
                    .iter()
                    .map(|b| b.map_or(f64::NAN, |d| d.as_secs_f64() * 1e3))
                    .collect(),
            );
        }
        self.trace = trace.arrivals_s.iter().map(|&s| (s.max(0.0) * 1e9).round() as u64).collect();
        self.done = vec![false; self.trace.len()];
        self.fm.start();
        self.arrivals_done = self.trace.is_empty();
        if let Some(&t0) = self.trace.first() {
            self.q.schedule(t0, Ev::Arrival(0));
        }
        if self.tick_ns > 0 {
            self.q.schedule(self.tick_ns, Ev::Tick);
        }
        while let Some((t, seq, ev)) = self.q.pop() {
            self.now = t;
            self.clock.set(t);
            self.events_processed += 1;
            match ev {
                Ev::Arrival(idx) => {
                    self.hash_event(t, seq, 1, idx as u64);
                    self.on_arrival(idx);
                }
                Ev::Wake(g, s) => {
                    self.hash_event(t, seq, 2, ((g as u64) << 16) | s as u64);
                    self.advance(g, s);
                }
                Ev::Tick => {
                    self.hash_event(t, seq, 3, 0);
                    self.on_tick();
                }
            }
        }
        assert_eq!(
            self.completed, self.accepted,
            "accepted requests must all complete before the event queue drains"
        );
        let span = secs(self.last_completion);
        self.fm.set_span_s(span);
        let summary = self.fm.summary();
        if let Some(e) = self.exposition.as_mut() {
            e.emit(secs(self.now), &summary, None);
        }
        // final health observation at the drain instant, then flush the
        // still-open cells so the journal covers the whole horizon
        self.observe_health();
        if let Some(h) = self.health.as_mut() {
            h.finish();
        }
        let health = self.health.take().map(HealthMonitor::into_journal);
        // end-of-run flush mirrors Server::shutdown: whatever spans the
        // rings still hold are appended to the trace file once
        if self.obs.active() {
            let _ = self.obs.recorder().flush("shutdown");
        }
        SimReport {
            summary,
            events: self.events,
            ticks: self.tap.ticks(),
            initial_groups: self.initial_groups,
            final_groups: self.active.len(),
            max_groups_seen: self.max_groups_seen,
            submitted: self.accepted,
            shed: self.shed,
            deadline_shed: self.deadline_shed,
            completed: self.completed,
            sim_seconds: secs(self.now),
            events_processed: self.events_processed,
            health,
            order_hash: self.order_hash,
            max_queue_seen: self.max_queue_seen,
        }
    }

    /// Feed the health monitor one snapshot of the cumulative fleet
    /// counters + latency histogram. Gated on the monitor's own sample
    /// interval so the histogram merge stays off non-sampling ticks.
    fn observe_health(&mut self) {
        let Some(h) = self.health.as_mut() else { return };
        if !h.due(self.now) {
            return;
        }
        let hist = self.fm.latency_histogram();
        h.observe(
            self.now,
            self.fm.submitted() as u64,
            self.fm.shed() as u64,
            self.fm.completed() as u64,
            &hist,
        );
    }

    fn hash_event(&mut self, t: u64, seq: u64, kind: u64, payload: u64) {
        let mut h = self.order_hash;
        for w in [t, seq, kind, payload] {
            for b in w.to_le_bytes() {
                h ^= b as u64;
                h = h.wrapping_mul(0x0000_0100_0000_01b3);
            }
        }
        self.order_hash = h;
    }

    fn group_load(&self, gi: usize) -> usize {
        self.groups[gi].workers.iter().map(|w| w.outstanding).sum()
    }

    // ---- admission -----------------------------------------------------

    fn on_arrival(&mut self, idx: usize) {
        // synthetic input draw in arrival order, mirroring replay: the
        // RNG advances even for requests that end up shed
        let mut sum = 0.0f32;
        for _ in 0..self.cfg.input_len {
            sum += self.rng.below(256) as f32;
        }
        // head sampling at submit, same sampler + seed as the server:
        // the same request ids are traced by both drivers
        let span = self.obs.sample(idx as u64);
        if self.tenancy {
            self.admit_tenant(idx, sum, span);
        } else {
            self.admit(idx, sum, span);
        }
        if idx + 1 < self.trace.len() {
            let t = self.trace[idx + 1].max(self.now);
            self.q.schedule(t, Ev::Arrival(idx + 1));
        } else {
            self.arrivals_done = true;
        }
    }

    /// Untenanted admission over the whole routable set (the original
    /// single-tenant path, untouched for bit-compatibility).
    fn admit(&mut self, idx: usize, sum: f32, mut span: Option<Box<RequestSpan>>) {
        let n = self.active.len();
        let first = preferred_group(&self.scheduler, n, |i| self.group_load(self.active[i]));
        let mut placed = self.try_admit(self.active[first], idx as u64, sum, &mut span);
        if placed.is_none() {
            for i in fallback_order(first, n, |i| self.group_load(self.active[i])) {
                placed = self.try_admit(self.active[i], idx as u64, sum, &mut span);
                if placed.is_some() {
                    break;
                }
            }
        }
        match placed {
            Some(gi) => {
                self.accepted += 1;
                self.fm.record_submitted();
                self.tap.record_submitted();
                self.advance(gi, 0);
            }
            None => {
                self.shed += 1;
                self.fm.record_shed();
                self.tap.record_shed();
                self.obs.shed(span.take(), 0);
            }
        }
    }

    /// Tenant-scoped admission, mirroring `RouterCore::dispatch_tenant`
    /// on the threaded server: route only over the arrival's tenant
    /// groups, and shed up front — before touching any queue — when the
    /// stamped deadline is infeasible even for the least-loaded member.
    /// The feasibility test is the same integer expression
    /// ([`deadline_feasible`]) in both time domains, so the two drivers
    /// make identical shed decisions on identical load states.
    fn admit_tenant(&mut self, idx: usize, sum: f32, mut span: Option<Box<RequestSpan>>) {
        let tenant = self.tags.get(idx).copied().unwrap_or(0);
        let members: Vec<usize> = self.tenant_members.get(tenant).cloned().unwrap_or_default();
        if members.is_empty() {
            // the threaded server reports Closed here; the sim has no
            // error channel, so the arrival counts as a shed
            self.shed += 1;
            self.fm.record_shed_for(tenant);
            self.tap.record_shed();
            self.obs.shed(span.take(), 0);
            return;
        }
        if let Some(budget) = self.tenant_budgets.get(tenant).copied().flatten() {
            let (min_load, best) = members
                .iter()
                .map(|&g| (self.group_load(g), g))
                .min()
                .expect("members checked non-empty");
            let est = self.est_service_ns.get(best).copied().unwrap_or(0);
            // the deadline is arrival + budget and the check runs at the
            // arrival instant, so the remaining slack is the full budget
            let remaining = i64::try_from(ns(budget)).unwrap_or(i64::MAX);
            if !deadline_feasible(remaining, min_load, est) {
                self.deadline_shed += 1;
                self.fm.record_deadline_shed(tenant);
                self.tap.record_shed();
                self.obs.shed(span.take(), 0);
                return;
            }
        }
        let n = members.len();
        let first =
            preferred_group(&self.tenant_schedulers[tenant], n, |i| self.group_load(members[i]));
        let mut placed = self.try_admit(members[first], idx as u64, sum, &mut span);
        if placed.is_none() {
            for i in fallback_order(first, n, |i| self.group_load(members[i])) {
                placed = self.try_admit(members[i], idx as u64, sum, &mut span);
                if placed.is_some() {
                    break;
                }
            }
        }
        match placed {
            Some(gi) => {
                self.accepted += 1;
                self.fm.record_submitted_for(tenant);
                self.tap.record_submitted();
                self.advance(gi, 0);
            }
            None => {
                self.shed += 1;
                self.fm.record_shed_for(tenant);
                self.tap.record_shed();
                self.obs.shed(span.take(), 0);
            }
        }
    }

    /// Mirror `RouterCore::try_entry`: admit into the group's stage-0
    /// bounded queue, or report full. An admitted request carries its
    /// span (Enqueue-stamped under the *slot* index, matching the
    /// completion's group field) into the queue.
    fn try_admit(
        &mut self,
        gi: usize,
        id: u64,
        sum: f32,
        span: &mut Option<Box<RequestSpan>>,
    ) -> Option<usize> {
        let depth = self.queue_depth;
        self.obs.stamp(span, SpanEvent::Enqueue, gi as u16, 0);
        let w = &mut self.groups[gi].workers[0];
        if w.queue.len() >= depth {
            return None;
        }
        w.outstanding += 1;
        w.queue.push_back(SimReq {
            id,
            sum,
            arrival: self.now,
            stage_arrival: self.now,
            stage_latencies: Vec::new(),
            stage_batches: Vec::new(),
            span: span.take(),
        });
        self.max_queue_seen = self.max_queue_seen.max(w.queue.len());
        Some(gi)
    }

    // ---- worker state machine ------------------------------------------

    /// Re-evaluate worker `(gi, s)` at the current virtual time. The
    /// steps mirror one iteration of the thread worker loop: finish any
    /// blocked downstream forward, reap ready batches oldest-first,
    /// close a due/full gather, then open a new gather if idle work is
    /// queued. Idempotent: spurious wakes are no-ops.
    fn advance(&mut self, gi: usize, s: usize) {
        loop {
            if !self.drain_blocked(gi, s) {
                return; // still stalled on a full downstream queue
            }
            if let Some(flight) = self.pop_ready_flight(gi, s) {
                self.complete_batch(gi, s, flight.reqs);
                continue; // forwards may have unblocked/reblocked us
            }
            self.feed_gather(gi, s);
            if self.close_gather_if_due(gi, s) {
                continue; // submit may free the queue for a new gather
            }
            if !self.open_gather(gi, s) {
                return;
            }
        }
    }

    /// Move blocked forwards into the downstream queue while it has
    /// room. Returns false while any remain (upstream worker stalled).
    fn drain_blocked(&mut self, gi: usize, s: usize) -> bool {
        if self.groups[gi].workers[s].blocked.is_empty() {
            return true;
        }
        let depth = self.queue_depth;
        let mut moved = false;
        loop {
            let (up, down) = self.groups[gi].workers.split_at_mut(s + 1);
            let w = &mut up[s];
            let d = &mut down[0];
            if w.blocked.is_empty() || d.queue.len() >= depth {
                break;
            }
            let req = w.blocked.pop_front().unwrap();
            w.outstanding -= 1; // left the upstream stage for real
            d.queue.push_back(req);
            moved = true;
        }
        let qlen = self.groups[gi].workers[s + 1].queue.len();
        self.max_queue_seen = self.max_queue_seen.max(qlen);
        if moved {
            self.advance(gi, s + 1);
        }
        self.groups[gi].workers[s].blocked.is_empty()
    }

    fn pop_ready_flight(&mut self, gi: usize, s: usize) -> Option<Flight> {
        let w = &mut self.groups[gi].workers[s];
        if w.in_flight.front().is_some_and(|f| f.ready_at <= self.now) {
            w.in_flight.pop_front()
        } else {
            None
        }
    }

    /// Absorb queued frames into an open gather (the thread worker's
    /// recv-with-deadline picks stragglers straight off the channel).
    fn feed_gather(&mut self, gi: usize, s: usize) {
        let w = &mut self.groups[gi].workers[s];
        if let Some(g) = w.gather.as_mut() {
            while g.reqs.len() < g.cap {
                match w.queue.pop_front() {
                    Some(mut r) => {
                        self.obs.stamp(&mut r.span, SpanEvent::Gather, gi as u16, s as u16);
                        g.reqs.push(r);
                    }
                    None => break,
                }
            }
        }
    }

    /// Close the open gather when full or past its deadline; submit the
    /// batch to the backend. Returns true if a batch was submitted.
    fn close_gather_if_due(&mut self, gi: usize, s: usize) -> bool {
        let w = &mut self.groups[gi].workers[s];
        let due = match w.gather.as_ref() {
            Some(g) => g.reqs.len() >= g.cap || self.now >= g.deadline,
            None => return false,
        };
        if !due {
            return false;
        }
        let g = w.gather.take().unwrap();
        self.submit_batch(gi, s, g.reqs);
        true
    }

    /// Open a new gather if the worker is free (not mid-service, window
    /// has room) and frames are queued. Returns true if progress was
    /// made (a gather opened — it may close immediately on the next loop
    /// iteration if already full or zero-wait).
    fn open_gather(&mut self, gi: usize, s: usize) -> bool {
        let depth_window = self.window;
        let w = &mut self.groups[gi].workers[s];
        if w.gather.is_some()
            || w.busy_until > self.now
            || w.in_flight.len() >= depth_window
            || w.queue.is_empty()
        {
            return false;
        }
        let cfg = w.cfg;
        let mut reqs = Vec::with_capacity(cfg.max_batch.min(w.queue.len()));
        while reqs.len() < cfg.max_batch {
            match w.queue.pop_front() {
                Some(mut r) => {
                    self.obs.stamp(&mut r.span, SpanEvent::Gather, gi as u16, s as u16);
                    reqs.push(r);
                }
                None => break,
            }
        }
        let deadline = self.now + ns(cfg.max_wait);
        w.gather = Some(Gather { reqs, deadline, cap: cfg.max_batch });
        if deadline > self.now {
            self.q.schedule(deadline, Ev::Wake(gi, s));
        }
        // frames left the bounded queue: a stalled upstream stage can
        // push its blocked forwards now
        if s > 0 && !self.groups[gi].workers[s - 1].blocked.is_empty() {
            self.advance(gi, s - 1);
        }
        true
    }

    /// Submit a formed batch to the worker's backend: store-and-forward
    /// occupies the worker for the whole service; overlapped transfer
    /// frees it after `xfer · k` while the device queue computes.
    fn submit_batch(&mut self, gi: usize, s: usize, mut reqs: Vec<SimReq>) {
        if self.obs.active() {
            for r in &mut reqs {
                self.obs.stamp(&mut r.span, SpanEvent::Dispatch, gi as u16, s as u16);
            }
        }
        let k = reqs.len() as u32;
        let w = &mut self.groups[gi].workers[s];
        match w.backend {
            SimBackend::Mock { base, per_item } => {
                let ready = self.now + ns(base) + ns(per_item) * k as u64;
                w.busy_until = ready;
                w.in_flight.push_back(Flight { ready_at: ready, reqs });
                self.q.schedule(ready, Ev::Wake(gi, s));
            }
            SimBackend::Pipelined { xfer_per_item, compute_per_item } => {
                let tx_done = self.now + ns(xfer_per_item) * k as u64;
                let start = w.device_free.max(tx_done);
                let ready = start + ns(compute_per_item) * k as u64;
                w.device_free = ready;
                w.busy_until = tx_done;
                w.in_flight.push_back(Flight { ready_at: ready, reqs });
                self.q.schedule(tx_done, Ev::Wake(gi, s));
                self.q.schedule(ready, Ev::Wake(gi, s));
            }
        }
    }

    /// Process a ready batch: final stages emit completions into the
    /// metrics/signal streams; mid-chain stages stamp the per-stage
    /// latency and forward each frame into the next stage's bounded
    /// queue (parking in `blocked` — upstream stall — when it is full).
    fn complete_batch(&mut self, gi: usize, s: usize, reqs: Vec<SimReq>) {
        let k = reqs.len();
        let last = s + 1 == self.groups[gi].workers.len();
        if last {
            for mut req in reqs {
                if !req.stage_latencies.is_empty() {
                    let hop = Duration::from_nanos(self.now - req.stage_arrival);
                    req.stage_latencies.push(hop);
                    req.stage_batches.push(k);
                }
                if self.obs.active() {
                    self.obs.stamp(&mut req.span, SpanEvent::Reap, gi as u16, s as u16);
                    self.obs.complete(&mut req.span, &self.rings[gi][s], gi as u16, s as u16);
                    self.obs.recycle(req.span.take());
                }
                let c = Completion {
                    id: req.id,
                    output: vec![req.sum, k as f32],
                    latency: Duration::from_nanos(self.now - req.arrival),
                    batch_size: k,
                    group: gi,
                    stage: s,
                    stage_latencies: req.stage_latencies,
                    stage_batches: req.stage_batches,
                    span: None,
                };
                self.fm.record(&c);
                self.tap.record_completion(c.latency);
                let idx = req.id as usize;
                assert!(!self.done[idx], "request {idx} completed twice");
                self.done[idx] = true;
                self.completed += 1;
                self.last_completion = self.now;
            }
            self.groups[gi].workers[s].outstanding -= k;
        } else {
            let depth = self.queue_depth;
            let mut forwarded_any = false;
            for mut req in reqs {
                let hop = Duration::from_nanos(self.now - req.stage_arrival);
                req.stage_latencies.push(hop);
                req.stage_batches.push(k);
                req.stage_arrival = self.now;
                if self.obs.active() {
                    self.obs.stamp(&mut req.span, SpanEvent::Reap, gi as u16, s as u16);
                    // stamped before the forward attempt, like the thread
                    // Forward sink before its blocking send
                    self.obs.stamp(&mut req.span, SpanEvent::LinkHop, gi as u16, s as u16);
                }
                // the stage's output row is [Σ inputs, k]; its sum —
                // the next stage's input sum — is Σ + k
                req.sum += k as f32;
                let (up, down) = self.groups[gi].workers.split_at_mut(s + 1);
                let w = &mut up[s];
                let d = &mut down[0];
                // increment-before-send, like the chain Forward sink
                d.outstanding += 1;
                if w.blocked.is_empty() && d.queue.len() < depth {
                    w.outstanding -= 1;
                    d.queue.push_back(req);
                    self.max_queue_seen = self.max_queue_seen.max(d.queue.len());
                    forwarded_any = true;
                } else {
                    w.blocked.push_back(req);
                }
            }
            if forwarded_any {
                self.advance(gi, s + 1);
            }
        }
    }

    // ---- control plane on virtual ticks --------------------------------

    /// One control tick, mirroring `control::run_loop::control_tick`:
    /// observe utilization, close the signal window, autoscale, then
    /// SLO-retune batching per routable group.
    fn on_tick(&mut self) {
        let at_s = secs(self.now);
        let outstanding: Vec<usize> = self
            .active
            .iter()
            .flat_map(|&gi| self.groups[gi].workers.iter().map(|w| w.outstanding))
            .collect();
        self.tap.observe_utilization(&outstanding, self.queue_depth);
        let sig = self.tap.tick();
        let ctx = SignalCtx::from_signals(&sig);
        // anomaly triggers read the closed window: a shed burst or p99
        // budget breach flushes the span rings to the trace file at the
        // virtual instant it happened (the sim has no worker deaths)
        if self.obs.active() {
            self.obs.recorder().observe(sig.p99_ms, sig.shed, 0);
        }
        // long-horizon health collection rides the same tick cadence
        self.observe_health();
        let decision = self.scaler.as_mut().map(|sc| sc.decide(&sig, self.active.len()));
        match decision {
            Some(ScaleDecision::Out(k)) => {
                let from = self.active.len();
                let added = self.scale_out(k);
                if added > 0 {
                    self.scaler.as_mut().unwrap().note_action(sig.tick);
                    self.events.push(ControlEvent {
                        tick: sig.tick,
                        at_s,
                        kind: ControlEventKind::ScaleOut { from, to: from + added },
                        ctx,
                    });
                }
            }
            Some(ScaleDecision::In(k)) => {
                let from = self.active.len();
                let removed = self.scale_in(k);
                if removed > 0 {
                    self.scaler.as_mut().unwrap().note_action(sig.tick);
                    self.events.push(ControlEvent {
                        tick: sig.tick,
                        at_s,
                        kind: ControlEventKind::ScaleIn { from, to: from - removed },
                        ctx,
                    });
                }
            }
            Some(ScaleDecision::Hold) | None => {}
        }
        if let Some(sl) = self.slo.take() {
            for pos in 0..self.active.len() {
                let gi = self.active[pos];
                if self.groups[gi].workers.len() == 1 {
                    let cur = self.groups[gi].workers[0].cfg;
                    let next = truncate_cfg(sl.adjust(sig.p99_ms, cur));
                    if next != cur {
                        self.groups[gi].workers[0].cfg = next;
                        self.events.push(ControlEvent {
                            tick: sig.tick,
                            at_s,
                            kind: ControlEventKind::SloAdjust {
                                group: pos,
                                stage: 0,
                                max_batch: next.max_batch,
                                max_wait: next.max_wait,
                            },
                            ctx,
                        });
                    }
                } else {
                    let next = sl.adjust(sig.p99_ms, self.groups[gi].slo_base);
                    self.groups[gi].slo_base = next;
                    let tuned = sl.co_tune_chain(&self.groups[gi].service, next);
                    for (stage, t) in tuned.into_iter().enumerate() {
                        let t = truncate_cfg(t);
                        if stage < self.groups[gi].workers.len()
                            && t != self.groups[gi].workers[stage].cfg
                        {
                            self.groups[gi].workers[stage].cfg = t;
                            self.events.push(ControlEvent {
                                tick: sig.tick,
                                at_s,
                                kind: ControlEventKind::SloAdjust {
                                    group: pos,
                                    stage,
                                    max_batch: t.max_batch,
                                    max_wait: t.max_wait,
                                },
                                ctx,
                            });
                        }
                    }
                }
            }
            self.slo = Some(sl);
        }
        // live exposition on the virtual clock: the due() gate keeps
        // summary construction (histogram merging) off non-emitting ticks
        if self.exposition.as_ref().is_some_and(|e| e.due(at_s)) {
            self.fm.set_span_s(at_s);
            let s = self.fm.summary();
            if let Some(e) = self.exposition.as_mut() {
                e.emit(at_s, &s, Some(&sig));
            }
        }
        let drained = self.arrivals_done && self.completed == self.accepted;
        if !drained {
            self.q.schedule(self.now + self.tick_ns, Ev::Tick);
        } else if self.trailing_left > 0 {
            self.trailing_left -= 1;
            self.q.schedule(self.now + self.tick_ns, Ev::Tick);
        }
    }

    /// Activate up to `want` standby slots, fastest capacity first (ties
    /// to the lowest slot index) — the simulated analogue of
    /// capacity-ranked placement. Returns how many were activated.
    fn scale_out(&mut self, want: usize) -> usize {
        let take = want.min(self.standby.len());
        if take == 0 {
            return 0;
        }
        let groups = &self.groups;
        self.standby.sort_by(|&a, &b| {
            groups[b].fps.partial_cmp(&groups[a].fps).unwrap().then(a.cmp(&b))
        });
        for _ in 0..take {
            let gi = self.standby.remove(0);
            self.active.push(gi);
        }
        self.scheduler = Self::build_scheduler(&self.policy, &self.groups, &self.active);
        if self.tenancy {
            self.rebuild_tenant_state();
        }
        self.max_groups_seen = self.max_groups_seen.max(self.active.len());
        take
    }

    /// Retire up to `want` routable groups, slowest capacity first (ties
    /// to the newest slot — highest router position), never below one.
    /// Retired groups finish their in-flight work (virtual drain) but
    /// receive no new admissions; their slots return to standby.
    fn scale_in(&mut self, want: usize) -> usize {
        let removable = self.active.len().saturating_sub(1);
        let take = want.min(removable);
        if take == 0 {
            return 0;
        }
        for _ in 0..take {
            let mut victim_pos = 0usize;
            for pos in 1..self.active.len() {
                let (v, p) = (self.active[victim_pos], self.active[pos]);
                if self.groups[p].fps < self.groups[v].fps
                    || (self.groups[p].fps == self.groups[v].fps && pos > victim_pos)
                {
                    victim_pos = pos;
                }
            }
            let gi = self.active.remove(victim_pos);
            self.standby.push(gi);
        }
        self.scheduler = Self::build_scheduler(&self.policy, &self.groups, &self.active);
        if self.tenancy {
            self.rebuild_tenant_state();
        }
        take
    }
}
