//! Deterministic xoshiro256** PRNG (Blackman & Vigna), seeded via
//! splitmix64. All stochastic components (GA packer, simulated annealing,
//! workload generators, property tests) draw from this so every experiment
//! is reproducible from a single seed.

/// xoshiro256** 1.0
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// Derive an independent child generator (for per-worker streams).
    pub fn split(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }

    /// Deterministic generator for stream `stream` of `seed`, independent of
    /// any other stream. Unlike [`Rng::split`] there is no sequential
    /// dependency between streams, so parallel workers (the island GA demes)
    /// can each construct their own generator from (seed, index) and the
    /// result is identical no matter how work is scheduled onto threads.
    pub fn for_stream(seed: u64, stream: u64) -> Rng {
        let mut sm = seed;
        let base = splitmix64(&mut sm);
        Rng::new(base ^ stream.wrapping_add(1).wrapping_mul(0xD1B54A32D192ED03))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)`. Uses Lemire's rejection method (unbiased).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in `[lo, hi)`.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi, "empty range {lo}..{hi}");
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli draw.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Exponential variate with the given rate (Poisson inter-arrivals).
    pub fn exp(&mut self, rate: f64) -> f64 {
        let u = loop {
            let u = self.f64();
            if u > 0.0 {
                break u;
            }
        };
        -u.ln() / rate
    }

    /// Pick a uniformly random element.
    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.range(0, xs.len())]
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.range(0, i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let (mut a, mut b) = (Rng::new(1), Rng::new(2));
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(3);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let v = r.below(7) as usize;
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues hit");
    }

    #[test]
    fn f64_unit_interval_mean() {
        let mut r = Rng::new(4);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn exp_mean_close_to_inverse_rate() {
        let mut r = Rng::new(5);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| r.exp(4.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.25).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(6);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>(), "shuffled order changed");
    }

    #[test]
    fn split_streams_are_independent() {
        let mut root = Rng::new(9);
        let mut a = root.split();
        let mut b = root.split();
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn for_stream_is_deterministic_and_distinct() {
        let mut a = Rng::for_stream(2020, 3);
        let mut b = Rng::for_stream(2020, 3);
        for _ in 0..50 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let firsts: Vec<u64> =
            (0..8).map(|s| Rng::for_stream(2020, s).next_u64()).collect();
        let mut uniq = firsts.clone();
        uniq.sort_unstable();
        uniq.dedup();
        assert_eq!(uniq.len(), firsts.len(), "streams collide: {firsts:?}");
        assert_ne!(
            Rng::for_stream(2020, 0).next_u64(),
            Rng::for_stream(2021, 0).next_u64()
        );
    }
}
