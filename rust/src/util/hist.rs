//! Fixed-bucket log-scale latency histogram — the allocation-free
//! replacement for the sort-the-whole-vector percentile path in
//! [`crate::coordinator::metrics`].
//!
//! The old [`crate::util::stats::summarize`] keeps every sample in a
//! `Vec<f64>` and sorts it at summary time: one heap push per completion
//! on the hot path and an `O(n log n)` sort per report. A
//! [`LogHistogram`] records a sample with two array writes and a handful
//! of float ops into a fixed 512-bucket table, so the steady-state
//! recording path never allocates and summarizing is an `O(buckets)`
//! walk.
//!
//! **Error bound.** Buckets are geometric with [`BUCKETS_PER_OCTAVE`]
//! buckets per factor of two, i.e. a bucket width of `2^(1/16) ≈ 1.0443`.
//! A percentile is reported as the geometric midpoint of its bucket
//! (clamped to the exact observed `[min, max]`), so the reported value is
//! within half a bucket — **±2.2 % relative** — of the exact order
//! statistic. Count, mean, min, max and the stddev (via `Σx²`) are exact.
//! The covered range is `[1e-3, ~4.3e6]` in the caller's unit
//! (milliseconds for the serving metrics: 1 µs up to ~72 minutes);
//! values outside clamp into the edge buckets but still update the exact
//! min/max/mean.

use crate::util::stats::Summary;

/// Geometric buckets per factor of two; the bucket width is
/// `2^(1/BUCKETS_PER_OCTAVE)`.
pub const BUCKETS_PER_OCTAVE: usize = 16;

/// Total bucket count: 32 octaves × 16 buckets.
pub const BUCKETS: usize = 32 * BUCKETS_PER_OCTAVE;

/// Lower edge of bucket 0 (values at or below it land there).
const MIN_TRACKED: f64 = 1e-3;

/// Streaming log-scale histogram with exact moments.
#[derive(Clone, Debug)]
pub struct LogHistogram {
    counts: Vec<u64>,
    total: u64,
    sum: f64,
    sumsq: f64,
    min: f64,
    max: f64,
}

impl Default for LogHistogram {
    fn default() -> Self {
        LogHistogram {
            counts: vec![0; BUCKETS],
            total: 0,
            sum: 0.0,
            sumsq: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }
}

impl LogHistogram {
    /// Empty histogram (the bucket table is the only allocation it will
    /// ever make).
    pub fn new() -> LogHistogram {
        LogHistogram::default()
    }

    fn bucket_of(v: f64) -> usize {
        if v <= MIN_TRACKED {
            return 0;
        }
        let idx = ((v / MIN_TRACKED).log2() * BUCKETS_PER_OCTAVE as f64) as usize;
        idx.min(BUCKETS - 1)
    }

    /// Geometric midpoint of bucket `i` — the value a percentile landing
    /// in that bucket is reported as (before min/max clamping).
    fn representative(i: usize) -> f64 {
        MIN_TRACKED * ((i as f64 + 0.5) / BUCKETS_PER_OCTAVE as f64).exp2()
    }

    /// Record one sample. Negative and non-finite values are clamped to
    /// zero (they land in the bottom bucket and pull the exact min down
    /// to 0). No allocation.
    pub fn record(&mut self, v: f64) {
        let v = if v.is_finite() && v > 0.0 { v } else { 0.0 };
        self.counts[Self::bucket_of(v)] += 1;
        self.total += 1;
        self.sum += v;
        self.sumsq += v * v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    /// Samples recorded.
    pub fn count(&self) -> u64 {
        self.total
    }

    /// Exact mean (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum / self.total as f64
        }
    }

    /// Exact minimum observed (0 when empty).
    pub fn min(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.min
        }
    }

    /// Exact maximum observed (0 when empty).
    pub fn max(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.max
        }
    }

    /// Population standard deviation from the exact `Σx`/`Σx²` moments.
    pub fn stddev(&self) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let mean = self.mean();
        (self.sumsq / self.total as f64 - mean * mean).max(0.0).sqrt()
    }

    /// Merge another histogram into this one, bucket by bucket. Because
    /// both sides share the same fixed geometric bucket edges, merging
    /// loses **no** precision beyond what each histogram already had:
    /// percentiles of the merged histogram are still within half a
    /// bucket (±2.2 %) of the exact order statistic over the union of
    /// samples, and the moments (count/mean/stddev) and min/max stay
    /// exact. This is how per-group collectors aggregate into fleet-wide
    /// percentiles without ever re-recording samples.
    pub fn merge(&mut self, other: &LogHistogram) {
        if other.total == 0 {
            return;
        }
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.total += other.total;
        self.sum += other.sum;
        self.sumsq += other.sumsq;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    /// Cheap copy of the current state, for later [`LogHistogram::diff`].
    /// One 512-slot bucket copy — interval bookkeeping on a snapshot
    /// cadence, never per sample.
    pub fn snapshot(&self) -> LogHistogram {
        self.clone()
    }

    /// The histogram of samples recorded **since** `baseline` was
    /// snapshotted from this same stream: bucket-wise and moment-wise
    /// subtraction. `baseline` must be an earlier snapshot of this
    /// histogram (counts can only have grown); anything else is a logic
    /// error and the subtraction saturates at zero rather than wrapping.
    ///
    /// The interval's exact min/max are unknowable from cumulative state,
    /// so they are bounded by the edges of the first and last occupied
    /// diff bucket (bucket 0's lower edge is 0). Percentiles therefore
    /// stay within one bucket width, as ever; `mean`/`stddev` stay exact.
    pub fn diff(&self, baseline: &LogHistogram) -> LogHistogram {
        let mut out = LogHistogram::new();
        debug_assert!(self.total >= baseline.total, "diff against a non-prefix baseline");
        for (o, (a, b)) in out.counts.iter_mut().zip(self.counts.iter().zip(&baseline.counts)) {
            *o = a.saturating_sub(*b);
        }
        out.total = self.total.saturating_sub(baseline.total);
        if out.total == 0 {
            return out; // empty interval: keep the pristine zero moments
        }
        out.sum = (self.sum - baseline.sum).max(0.0);
        out.sumsq = (self.sumsq - baseline.sumsq).max(0.0);
        let first = out.counts.iter().position(|&c| c > 0).unwrap_or(0);
        let last = out.counts.iter().rposition(|&c| c > 0).unwrap_or(0);
        out.min = if first == 0 {
            0.0
        } else {
            (MIN_TRACKED * (first as f64 / BUCKETS_PER_OCTAVE as f64).exp2()).min(self.max)
        };
        out.max =
            (MIN_TRACKED * ((last as f64 + 1.0) / BUCKETS_PER_OCTAVE as f64).exp2()).min(self.max);
        out
    }

    /// Percentile `p` in `[0, 100]`: the representative of the bucket
    /// holding the `ceil(p/100 · n)`-th smallest sample, clamped to the
    /// exact observed `[min, max]` — within half a bucket width (±2.2 %)
    /// of the exact order statistic. Returns 0 when empty.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let p = p.clamp(0.0, 100.0);
        let target = ((p / 100.0 * self.total as f64).ceil() as u64).clamp(1, self.total);
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= target {
                return Self::representative(i).clamp(self.min, self.max);
            }
        }
        self.max
    }

    /// Full [`Summary`]: exact n/mean/stddev/min/max, bucketed
    /// median/p95/p99. Panics when empty (mirrors
    /// [`crate::util::stats::summarize`]).
    pub fn summary(&self) -> Summary {
        assert!(self.total > 0, "summary of empty histogram");
        Summary {
            n: self.total as usize,
            mean: self.mean(),
            median: self.percentile(50.0),
            stddev: self.stddev(),
            min: self.min(),
            max: self.max(),
            p95: self.percentile(95.0),
            p99: self.percentile(99.0),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use crate::util::stats::{percentile, summarize};

    /// Relative tolerance: one bucket width `2^(1/16) − 1 ≈ 4.4 %` covers
    /// the half-bucket representative error on both of two adjacent order
    /// statistics the exact linear interpolation can fall between.
    const TOL: f64 = 0.045;

    fn close(got: f64, want: f64) -> bool {
        if want == 0.0 {
            return got.abs() < 1e-12;
        }
        (got / want - 1.0).abs() <= TOL
    }

    #[test]
    fn empty_histogram_reports_zeroes() {
        let h = LogHistogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.percentile(99.0), 0.0);
        assert_eq!(h.min(), 0.0);
        assert_eq!(h.max(), 0.0);
    }

    #[test]
    #[should_panic]
    fn empty_summary_panics() {
        LogHistogram::new().summary();
    }

    #[test]
    fn moments_are_exact() {
        let mut h = LogHistogram::new();
        for v in [1.0, 2.0, 3.0, 4.0] {
            h.record(v);
        }
        assert_eq!(h.count(), 4);
        assert_eq!(h.mean(), 2.5);
        assert_eq!(h.min(), 1.0);
        assert_eq!(h.max(), 4.0);
        let exact = summarize(&[1.0, 2.0, 3.0, 4.0]);
        assert!((h.stddev() - exact.stddev).abs() < 1e-9);
    }

    #[test]
    fn constant_samples_collapse_to_the_value() {
        let mut h = LogHistogram::new();
        for _ in 0..100 {
            h.record(7.5);
        }
        // min == max clamps every percentile to the exact value
        assert_eq!(h.percentile(50.0), 7.5);
        assert_eq!(h.percentile(99.0), 7.5);
    }

    #[test]
    fn percentiles_track_exact_sorted_values_within_one_bucket() {
        // the acceptance cross-check: p50/p95/p99 of a heavy-tailed
        // sample agree with the exact sorted-vector percentiles within
        // one bucket width
        let mut rng = Rng::new(99);
        let mut h = LogHistogram::new();
        let mut exact = Vec::new();
        for _ in 0..5000 {
            // log-uniform over ~[0.1, 1000] ms
            let u = rng.below(1_000_000) as f64 / 1_000_000.0;
            let v = 0.1 * 10f64.powf(4.0 * u);
            h.record(v);
            exact.push(v);
        }
        exact.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for p in [50.0, 90.0, 95.0, 99.0] {
            let want = percentile(&exact, p);
            let got = h.percentile(p);
            assert!(close(got, want), "p{p}: hist {got} vs exact {want}");
        }
        let s = h.summary();
        let es = summarize(&exact);
        assert!(close(s.median, es.median));
        assert!(close(s.p95, es.p95));
        assert!(close(s.p99, es.p99));
        assert!((s.mean - es.mean).abs() < 1e-9, "mean must stay exact");
        assert_eq!(s.max, es.max, "max must stay exact");
    }

    #[test]
    fn extreme_and_degenerate_values_stay_bounded() {
        let mut h = LogHistogram::new();
        h.record(-5.0); // clamps to 0, bottom bucket
        h.record(0.0);
        h.record(f64::NAN); // clamps to 0
        h.record(1e12); // beyond the top bucket edge
        assert_eq!(h.count(), 4);
        assert_eq!(h.min(), 0.0);
        assert_eq!(h.max(), 1e12);
        // percentiles stay inside the exact observed range
        let p99 = h.percentile(99.0);
        assert!((0.0..=1e12).contains(&p99));
        assert_eq!(h.percentile(100.0), 1e12, "p100 clamps up to the exact max");
        assert_eq!(h.percentile(0.0), 0.0, "p0 clamps down to the exact min");
    }

    #[test]
    fn merge_equals_recording_everything_into_one() {
        // splitting a sample stream across k histograms and merging must
        // give bit-identical buckets and moments to one big histogram
        let mut rng = Rng::new(7);
        let mut parts = vec![LogHistogram::new(); 3];
        let mut whole = LogHistogram::new();
        for i in 0..3000 {
            let u = rng.below(1_000_000) as f64 / 1_000_000.0;
            let v = 0.05 * 10f64.powf(5.0 * u);
            parts[i % 3].record(v);
            whole.record(v);
        }
        let mut merged = LogHistogram::new();
        for p in &parts {
            merged.merge(p);
        }
        assert_eq!(merged.count(), whole.count());
        assert_eq!(merged.min(), whole.min());
        assert_eq!(merged.max(), whole.max());
        assert!((merged.mean() - whole.mean()).abs() < 1e-9);
        assert!((merged.stddev() - whole.stddev()).abs() < 1e-9);
        for p in [10.0, 50.0, 90.0, 95.0, 99.0, 99.9] {
            assert_eq!(merged.percentile(p), whole.percentile(p), "p{p} diverged");
        }
    }

    #[test]
    fn merge_with_empty_is_identity_both_ways() {
        let mut h = LogHistogram::new();
        h.record(3.0);
        h.record(30.0);
        let before = h.summary();
        h.merge(&LogHistogram::new());
        let after = h.summary();
        assert_eq!(before.n, after.n);
        assert_eq!(before.min, after.min);
        assert_eq!(before.max, after.max);
        let mut empty = LogHistogram::new();
        empty.merge(&h);
        assert_eq!(empty.count(), 2);
        assert_eq!(empty.min(), 3.0);
        assert_eq!(empty.max(), 30.0);
    }

    #[test]
    fn diff_of_identical_snapshots_is_empty() {
        let mut h = LogHistogram::new();
        for v in [1.0, 5.0, 25.0] {
            h.record(v);
        }
        let base = h.snapshot();
        let d = h.diff(&base);
        assert_eq!(d.count(), 0, "no samples between snapshot and diff");
        assert_eq!(d.mean(), 0.0);
        assert_eq!(d.percentile(99.0), 0.0);
        assert_eq!(d.min(), 0.0);
        assert_eq!(d.max(), 0.0);
        // and a fresh histogram diffed against a fresh baseline is empty too
        let empty = LogHistogram::new();
        assert_eq!(empty.diff(&LogHistogram::new()).count(), 0);
    }

    #[test]
    fn diff_single_bucket_interval_reports_that_bucket() {
        let mut h = LogHistogram::new();
        for _ in 0..50 {
            h.record(100.0); // lifetime history far above the interval
        }
        let base = h.snapshot();
        for _ in 0..7 {
            h.record(2.0); // the whole interval lands in one bucket
        }
        let d = h.diff(&base);
        assert_eq!(d.count(), 7);
        assert!((d.mean() - 2.0).abs() < 1e-9, "interval mean is exact");
        for p in [1.0, 50.0, 99.0] {
            let got = d.percentile(p);
            assert!(close(got, 2.0), "p{p} of single-bucket interval: {got}");
        }
        assert!(d.max() < 100.0, "interval max bound excludes lifetime samples");
        assert!(d.min() > 0.0 && d.min() <= 2.0);
    }

    #[test]
    fn diff_interval_percentiles_ignore_lifetime_history() {
        // lifetime: 5000 fast samples, then an interval of 500 slow ones;
        // the interval p99 must reflect the slow regime, which the
        // cumulative histogram's p99 hides
        let mut rng = Rng::new(11);
        let mut h = LogHistogram::new();
        for _ in 0..5000 {
            h.record(1.0 + rng.below(100) as f64 / 1000.0);
        }
        let base = h.snapshot();
        let mut exact = Vec::new();
        for _ in 0..500 {
            let v = 50.0 + rng.below(10_000) as f64 / 1000.0;
            h.record(v);
            exact.push(v);
        }
        exact.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let d = h.diff(&base);
        assert_eq!(d.count(), 500);
        let want = percentile(&exact, 99.0);
        let got = d.percentile(99.0);
        assert!(close(got, want), "interval p99 {got} vs exact {want}");
        assert!(h.percentile(50.0) < 2.0, "cumulative p50 still fast");
        assert!(d.percentile(50.0) > 45.0, "interval p50 is slow");
    }

    #[test]
    fn bucket_of_is_monotone() {
        let mut last = 0;
        let mut v = 5e-4;
        while v < 1e7 {
            let b = LogHistogram::bucket_of(v);
            assert!(b >= last, "bucket index regressed at {v}");
            assert!(b < BUCKETS);
            last = b;
            v *= 1.31;
        }
    }
}
