//! Benchmark harness (criterion is unavailable offline): warmup, repeated
//! timed samples, summary statistics, and aligned table output shared by all
//! `rust/benches/*` targets.

use std::time::Instant;

use super::stats::{summarize, Summary};

/// Benchmark configuration.
#[derive(Clone, Copy, Debug)]
pub struct BenchConfig {
    pub warmup_iters: usize,
    pub samples: usize,
    /// Iterations batched inside one timed sample (for very fast bodies).
    pub iters_per_sample: usize,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig { warmup_iters: 3, samples: 10, iters_per_sample: 1 }
    }
}

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub per_iter_secs: Summary,
}

impl BenchResult {
    pub fn mean_ms(&self) -> f64 {
        self.per_iter_secs.mean * 1e3
    }
}

/// Time `f`, returning per-iteration seconds statistics.
pub fn bench<F: FnMut()>(name: &str, cfg: BenchConfig, mut f: F) -> BenchResult {
    for _ in 0..cfg.warmup_iters {
        f();
    }
    let mut samples = Vec::with_capacity(cfg.samples);
    for _ in 0..cfg.samples {
        let t0 = Instant::now();
        for _ in 0..cfg.iters_per_sample {
            f();
        }
        samples.push(t0.elapsed().as_secs_f64() / cfg.iters_per_sample as f64);
    }
    BenchResult { name: name.to_string(), per_iter_secs: summarize(&samples) }
}

/// Serialize bench results as a JSON array (serde is unavailable offline;
/// the fields are flat floats/ints, so hand-rolling is safe). `{:?}` on the
/// name produces a quoted, escaped string — valid JSON for any name.
pub fn to_json(results: &[BenchResult]) -> String {
    let mut out = String::from("[");
    for (k, r) in results.iter().enumerate() {
        if k > 0 {
            out.push(',');
        }
        let s = &r.per_iter_secs;
        out.push_str(&format!(
            "{{\"name\":{:?},\"mean_ms\":{:.6},\"median_ms\":{:.6},\"stddev_ms\":{:.6},\"min_ms\":{:.6},\"max_ms\":{:.6},\"samples\":{}}}",
            r.name,
            s.mean * 1e3,
            s.median * 1e3,
            s.stddev * 1e3,
            s.min * 1e3,
            s.max * 1e3,
            s.n
        ));
    }
    out.push(']');
    out
}

/// Write bench results as JSON (the CI perf-trajectory artifact, e.g.
/// `BENCH_packing.json` from `benches/packer_ablation.rs`).
pub fn write_json(path: &std::path::Path, results: &[BenchResult]) -> std::io::Result<()> {
    std::fs::write(path, to_json(results))
}

/// Print a standard bench summary line.
pub fn report(r: &BenchResult) {
    let s = &r.per_iter_secs;
    println!(
        "bench {:<40} mean {:>10.3} ms  median {:>10.3} ms  sd {:>8.3} ms  (n={})",
        r.name,
        s.mean * 1e3,
        s.median * 1e3,
        s.stddev * 1e3,
        s.n
    );
}

/// Fixed-width text table (markdown-flavoured) used by every bench binary to
/// print the paper's tables next to our measured values.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new<S: Into<String>, I: IntoIterator<Item = S>>(headers: I) -> Table {
        Table { headers: headers.into_iter().map(Into::into).collect(), rows: vec![] }
    }

    pub fn row<S: Into<String>, I: IntoIterator<Item = S>>(&mut self, cells: I) {
        let row: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(row.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(row);
    }

    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| -> String {
            let mut s = String::from("|");
            for i in 0..ncol {
                s.push_str(&format!(" {:<w$} |", cells[i], w = widths[i]));
            }
            s
        };
        let mut out = line(&self.headers);
        out.push('\n');
        out.push('|');
        for w in &widths {
            out.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        for r in &self.rows {
            out.push('\n');
            out.push_str(&line(r));
        }
        out
    }

    /// Render as CSV (for EXPERIMENTS.md attachments / plotting).
    pub fn to_csv(&self) -> String {
        let mut out = self.headers.join(",");
        for r in &self.rows {
            out.push('\n');
            out.push_str(&r.join(","));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_positive_time() {
        let r = bench(
            "spin",
            BenchConfig { warmup_iters: 1, samples: 3, iters_per_sample: 2 },
            || {
                std::hint::black_box((0..1000).sum::<u64>());
            },
        );
        assert!(r.per_iter_secs.mean > 0.0);
        assert_eq!(r.per_iter_secs.n, 3);
    }

    #[test]
    fn table_render_aligns() {
        let mut t = Table::new(["name", "value"]);
        t.row(["a", "1"]);
        t.row(["longer-name", "2345"]);
        let s = t.render();
        assert!(s.contains("| name        | value |"));
        assert!(s.lines().count() == 4);
    }

    #[test]
    fn json_output_is_well_formed() {
        let r = bench(
            "js\"on", // name needing escaping
            BenchConfig { warmup_iters: 0, samples: 2, iters_per_sample: 1 },
            || {
                std::hint::black_box(1 + 1);
            },
        );
        let j = to_json(&[r.clone(), r]);
        assert!(j.starts_with('[') && j.ends_with(']'));
        assert!(j.contains("\"mean_ms\":"));
        assert!(j.contains("js\\\"on"), "{j}");
        assert_eq!(j.matches("\"samples\":2").count(), 2);
    }

    #[test]
    fn csv_roundtrip_shape() {
        let mut t = Table::new(["x", "y"]);
        t.row(["1", "2"]);
        assert_eq!(t.to_csv(), "x,y\n1,2");
    }

    #[test]
    #[should_panic]
    fn row_arity_checked() {
        let mut t = Table::new(["a", "b"]);
        t.row(["only-one"]);
    }
}
