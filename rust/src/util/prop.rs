//! Property-test mini-framework (proptest is unavailable offline).
//!
//! `check(seed, cases, gen, prop)` draws `cases` random inputs from `gen`
//! and asserts `prop` on each; on failure it retries with halved inputs via
//! the case's [`Shrink`] implementation (when provided) and reports the
//! minimal failing case together with the seed needed to replay it.

use super::rng::Rng;
use std::fmt::Debug;

/// Types that can propose smaller versions of themselves for shrinking.
pub trait Shrink: Sized {
    /// Candidate smaller cases, roughly ordered most-aggressive first.
    fn shrink(&self) -> Vec<Self> {
        Vec::new()
    }
}

impl Shrink for usize {
    fn shrink(&self) -> Vec<usize> {
        let mut out = Vec::new();
        if *self > 0 {
            out.push(self / 2);
            out.push(self - 1);
        }
        out
    }
}

impl Shrink for u64 {
    fn shrink(&self) -> Vec<u64> {
        let mut out = Vec::new();
        if *self > 0 {
            out.push(self / 2);
            out.push(self - 1);
        }
        out
    }
}

impl<T: Shrink + Clone> Shrink for Vec<T> {
    fn shrink(&self) -> Vec<Vec<T>> {
        let mut out = Vec::new();
        if self.is_empty() {
            return out;
        }
        // drop halves
        out.push(self[..self.len() / 2].to_vec());
        out.push(self[self.len() / 2..].to_vec());
        // shrink one element
        for (i, x) in self.iter().enumerate().take(4) {
            for sx in x.shrink() {
                let mut v = self.clone();
                v[i] = sx;
                out.push(v);
            }
        }
        out
    }
}

/// Run a property over `cases` random inputs. Panics with the (shrunk)
/// counterexample and replay seed on failure.
pub fn check<T, G, P>(seed: u64, cases: usize, mut gen: G, mut prop: P)
where
    T: Debug + Clone + Shrink,
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    let mut rng = Rng::new(seed);
    for case_idx in 0..cases {
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            // shrink
            let mut best = (input.clone(), msg.clone());
            let mut frontier = input.shrink();
            let mut budget = 300;
            while !frontier.is_empty() {
                let cand = frontier.remove(0); // most-aggressive first
                if budget == 0 {
                    break;
                }
                budget -= 1;
                if let Err(m) = prop(&cand) {
                    frontier = cand.shrink();
                    best = (cand, m);
                }
            }
            panic!(
                "property failed (seed={seed}, case {case_idx}/{cases}):\n  \
                 counterexample: {:?}\n  reason: {}",
                best.0, best.1
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(1, 200, |r| r.below(100), |&x| {
            if x < 100 {
                Ok(())
            } else {
                Err(format!("{x} >= 100"))
            }
        });
    }

    #[test]
    fn failing_property_shrinks() {
        let r = std::panic::catch_unwind(|| {
            check(2, 100, |r| r.below(1000) + 50, |&x| {
                if x < 50 {
                    Ok(())
                } else {
                    Err("too big".into())
                }
            });
        });
        let err = r.unwrap_err();
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_else(|| format!("{err:?}"));
        // shrinker must reach the boundary case 50
        assert!(msg.contains("counterexample: 50"), "got: {msg}");
    }

    #[test]
    fn vec_shrink_reduces_len() {
        let v = vec![1usize, 2, 3, 4];
        assert!(v.shrink().iter().any(|s| s.len() < v.len()));
    }
}
