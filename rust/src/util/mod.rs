//! Small self-contained substrates (offline image: no rand / clap /
//! criterion / proptest crates — see DESIGN.md "Offline-dependency
//! substitutions"). Each is a real implementation with its own tests, not a
//! stub.

pub mod args;
pub mod bench;
pub mod hist;
pub mod prop;
pub mod rng;
pub mod stats;

/// Integer ceiling division (used throughout the BRAM shape calculus).
#[inline]
pub const fn ceil_div(a: u64, b: u64) -> u64 {
    (a + b - 1) / b
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ceil_div_basics() {
        assert_eq!(ceil_div(0, 4), 0);
        assert_eq!(ceil_div(1, 4), 1);
        assert_eq!(ceil_div(4, 4), 1);
        assert_eq!(ceil_div(5, 4), 2);
        assert_eq!(ceil_div(8, 4), 2);
    }
}
