//! Minimal subcommand + flag argument parser (clap is unavailable offline).
//!
//! Grammar: `fcmp <subcommand> [--key value]... [--flag]... [positional]...`

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (not including argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with('-') {
                out.subcommand = it.next();
            }
        }
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                // --key=value or --key value or boolean --flag
                if let Some((k, v)) = key.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it.peek().map_or(false, |n| !n.starts_with("--")) {
                    out.options.insert(key.to_string(), it.next().unwrap());
                } else {
                    out.flags.push(key.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    /// Parse the real process arguments.
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} wants an integer, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} wants a number, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("pack --network rn50 --device u250 --hb 4");
        assert_eq!(a.subcommand.as_deref(), Some("pack"));
        assert_eq!(a.get("network"), Some("rn50"));
        assert_eq!(a.get_usize("hb", 0), 4);
    }

    #[test]
    fn equals_form_and_flags() {
        // NOTE grammar caveat: a bare `--flag` followed by a non-dash token
        // is parsed as an option (`--key value`); put positionals before
        // flags or use `--key=value` to disambiguate.
        let a = parse("serve --batch=8 file.txt --verbose");
        assert_eq!(a.get_usize("batch", 0), 8);
        assert!(a.has_flag("verbose"));
        assert_eq!(a.positional, vec!["file.txt"]);
    }

    #[test]
    fn flag_before_option_value_not_swallowed() {
        let a = parse("run --dry-run --seed 9");
        assert!(a.has_flag("dry-run"));
        assert_eq!(a.get_usize("seed", 0), 9);
    }

    #[test]
    fn defaults() {
        let a = parse("bench");
        assert_eq!(a.get_or("device", "u250"), "u250");
        assert_eq!(a.get_f64("ratio", 1.5), 1.5);
        assert!(!a.has_flag("verbose"));
    }

    #[test]
    fn no_subcommand_when_leading_flag() {
        let a = parse("--help");
        assert_eq!(a.subcommand, None);
        assert!(a.has_flag("help"));
    }
}
