//! Summary statistics for benchmarks and the serving metrics pipeline.

/// Summary of a sample set.
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub median: f64,
    pub stddev: f64,
    pub min: f64,
    pub max: f64,
    pub p95: f64,
    pub p99: f64,
}

/// Compute a [`Summary`]; panics on an empty sample.
pub fn summarize(samples: &[f64]) -> Summary {
    assert!(!samples.is_empty(), "summarize of empty sample");
    let n = samples.len();
    let mut sorted = samples.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = sorted.iter().sum::<f64>() / n as f64;
    let var = sorted.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
    Summary {
        n,
        mean,
        median: percentile(&sorted, 50.0),
        stddev: var.sqrt(),
        min: sorted[0],
        max: sorted[n - 1],
        p95: percentile(&sorted, 95.0),
        p99: percentile(&sorted, 99.0),
    }
}

/// Linear-interpolated percentile of an ascending-sorted slice.
pub fn percentile(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=100.0).contains(&p));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_of_constant() {
        let s = summarize(&[3.0; 10]);
        assert_eq!(s.mean, 3.0);
        assert_eq!(s.stddev, 0.0);
        assert_eq!(s.p99, 3.0);
    }

    #[test]
    fn summary_known_values() {
        let s = summarize(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.mean, 2.5);
        assert_eq!(s.median, 2.5);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
    }

    #[test]
    fn percentile_interpolates() {
        let v = [10.0, 20.0, 30.0];
        assert_eq!(percentile(&v, 0.0), 10.0);
        assert_eq!(percentile(&v, 50.0), 20.0);
        assert_eq!(percentile(&v, 100.0), 30.0);
        assert_eq!(percentile(&v, 25.0), 15.0);
    }

    #[test]
    #[should_panic]
    fn empty_sample_panics() {
        summarize(&[]);
    }
}
