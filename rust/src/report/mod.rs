//! Report layer: regenerates the paper's tables/figures as text/CSV.
//!
//! Each `table_*` / `fig_*` function assembles the full experiment from the
//! underlying modules and returns a [`crate::util::bench::Table`] whose rows
//! mirror the paper's rows, annotated with our measured values. The bench
//! binaries print these; EXPERIMENTS.md records paper-vs-measured.

use crate::device::{self, Device};
use crate::folding::{self, network_resources};
use crate::gals;
use crate::memory;
use crate::nn::{cnv, resnet50, CnvVariant, Network};
use crate::packing::{self, Constraints, Packer};
use crate::sim;
use crate::timing;
use crate::util::bench::Table;

/// Result of running the FCMP packing flow on one network/device pair.
pub struct PackOutcome {
    pub items: Vec<memory::PackItem>,
    pub packing: packing::Packing,
    pub report: packing::PackReport,
    pub baseline_brams: u64,
    pub baseline_eff: f64,
    /// Streamer + CDC logic overhead (kLUT), Table IV's "Logic" column.
    pub logic_kluts: f64,
}

/// Run the FCMP packing flow (paper §IV) on a network/device pair.
pub fn pack_network(
    net: &Network,
    dev: &Device,
    engine: &dyn Packer,
    bin_height: usize,
) -> PackOutcome {
    let bufs = memory::weight_buffers(net, dev.slrs.len());
    let items = memory::all_columns(&bufs);
    let c = Constraints::new(bin_height, !dev.is_monolithic());
    let (packing, report) = packing::run_packer(engine, &items, &c);
    let baseline_brams = memory::direct_brams(&bufs);
    let baseline_eff = memory::efficiency(memory::total_bits(&bufs), baseline_brams);
    // streams = column slices; DWCs appear for full odd-height bins (Fig 7b)
    let with_dwc = packing
        .bins
        .iter()
        .filter(|b| b.items.len() == bin_height && bin_height % 2 == 1)
        .count();
    let logic_kluts =
        gals::streamer_lut_overhead(items.len(), packing.bins.len(), with_dwc) / 1e3;
    PackOutcome { items, packing, report, baseline_brams, baseline_eff, logic_kluts }
}

/// Cached [`pack_network`]: fetches the packed design from the process-wide
/// [`crate::packing::cache`], packing on first miss — a fleet of identical
/// replicas (or a partitioner probing the same stage range twice) packs
/// once, not once per caller. `generations == 0` selects the deterministic
/// FFD baseline (fast feasibility sweeps); any other value runs the default
/// GA with that generation budget and `seed`. Empty item sets (shards made
/// of pool stages or packing-excluded layers only) short-circuit to a
/// zero-cost design.
pub fn pack_network_cached(
    net: &Network,
    dev: &Device,
    bin_height: usize,
    generations: usize,
    seed: u64,
) -> std::sync::Arc<packing::cache::CachedPack> {
    let key = packing::cache::PackKey::new(net, dev, bin_height, engine_tag(generations), seed);
    packing::cache::get_or_pack(key, || {
        let bufs = memory::weight_buffers(net, dev.slrs.len());
        if memory::all_columns(&bufs).is_empty() {
            return packing::cache::CachedPack {
                packing: packing::Packing::default(),
                report: packing::PackReport {
                    engine: "empty",
                    brams: 0,
                    efficiency: 1.0,
                    max_height: 0,
                    elapsed: std::time::Duration::ZERO,
                },
                baseline_brams: 0,
                logic_kluts: 0.0,
            };
        }
        let out = if generations == 0 {
            pack_network(net, dev, &packing::ffd::Ffd::new(), bin_height)
        } else {
            let mut ga = default_ga(net);
            ga.params.generations = generations;
            ga.params.seed = seed;
            pack_network(net, dev, &ga, bin_height)
        };
        packing::cache::CachedPack {
            packing: out.packing,
            report: out.report,
            baseline_brams: out.baseline_brams,
            logic_kluts: out.logic_kluts,
        }
    })
}

/// Engine identity string [`pack_network_cached`] keys the packing cache
/// with for a given generation budget (`"ffd"` for the deterministic
/// baseline, `"ga/N"` otherwise). The failure-repair path
/// ([`crate::control::repair`]) reconstructs cache keys with this exact
/// tag to tell migrated manifests from re-packs — keep the two in sync by
/// construction, not by convention.
pub fn engine_tag(generations: usize) -> String {
    if generations == 0 {
        "ffd".to_string()
    } else {
        format!("ga/{generations}")
    }
}

/// Default GA engine for a network (Table III hyper-parameters).
pub fn default_ga(net: &Network) -> packing::ga::Ga {
    if net.name.starts_with("CNV") {
        packing::ga::Ga::new(packing::ga::GaParams::cnv())
    } else {
        packing::ga::Ga::new(packing::ga::GaParams::rn50())
    }
}

/// Island-parallel default engine: same Table III parameters, split across
/// `islands` demes evolved on up to `threads` workers (0 = all cores). The
/// packing depends only on `(params, islands)` — never on `threads` — so
/// report tables stay reproducible across machines.
pub fn default_ga_parallel(net: &Network, islands: usize, threads: usize) -> packing::ga::Ga {
    let mut g = default_ga(net);
    g.params = g.params.with_islands(islands);
    g.threads = threads;
    g
}

/// Islands used by the report tables: fixed (for reproducibility of the
/// table values), sized so RN50-class sweeps saturate a small desktop.
pub const REPORT_ISLANDS: usize = 4;

/// Table I — resource utilization of FINN accelerators on Zynq 7020.
pub fn table1() -> Table {
    let dev = device::zynq_7020();
    let mut t = Table::new(["accelerator", "BRAM %", "LUT %", "DSP %", "paper (BRAM/LUT/DSP)"]);
    // paper Table I has five unlabeled BNN-Pynq rows; we regenerate the
    // full suite (MLPs + CNVs) against the published row values
    let rows: Vec<(Network, &str)> = vec![
        (crate::nn::sfc_w1a1(), "78 / 53 / 2"),
        (crate::nn::lfc_w1a1(), "88 / 49 / 11"),
        (cnv(CnvVariant::W1A1), "94 / 76 / 12"),
        (cnv(CnvVariant::W1A2), "100 / 70 / 15"),
        (cnv(CnvVariant::W2A2), "79 / 92 / 2"),
    ];
    for (net, paper) in rows {
        let r = network_resources(&net, &dev);
        t.row([
            net.name.clone(),
            format!("{:.0}", r.bram_pct(&dev)),
            format!("{:.0}", r.lut_pct(&dev)),
            format!("{:.0}", 100.0 * r.dsps / dev.dsp as f64),
            paper.to_string(),
        ]);
    }
    t
}

/// Fig. 2 — mapping efficiency decreases with parallelism.
pub fn fig2() -> Table {
    let mut t = Table::new(["parallelism", "buffer (w x d)", "BRAM18", "E %"]);
    // one conv layer (256 -> 256 channels, 3x3) at 1x / 2x / 4x compute
    for (mult, pe, simd) in [(1u64, 4u64, 32u64), (2, 8, 32), (4, 16, 32)] {
        let l = crate::nn::Layer {
            name: format!("conv-x{mult}"),
            kind: crate::nn::LayerKind::Conv,
            k: 3,
            c_in: 256,
            c_out: 256,
            stride: 1,
            pad: 1,
            ifm: 14,
            wbits: 1,
            abits: 2,
            pe,
            simd,
            exclude_from_packing: false,
        };
        let b = memory::WeightBuffer::from_layer(&l, 0);
        let brams = b.brams();
        t.row([
            format!("x{mult} (PE={pe} SIMD={simd})"),
            format!("{}x{}", b.width_bits, b.depth),
            format!("{brams}"),
            format!("{:.1}", 100.0 * memory::efficiency(b.bits(), brams)),
        ]);
    }
    t
}

/// Table II — ImageNet dataflow accelerator comparison (our RN50 row).
pub fn table2() -> Table {
    let mut t = Table::new([
        "accelerator", "Top-1 %", "TOp/s", "platform", "Fmax", "kLUT", "BRAM18", "FPS", "lat ms",
    ]);
    // published rows (Table II) for side-by-side shape comparison
    t.row(["DoReFaNet-DF [9]", "50", "11.4", "AWS F1", "155", "477", "1332", "5241", "-"]);
    t.row(["ReBNet Arch3 [13]", "41", "-", "VCU108", "200", "188", "3125", "170-520", "-"]);
    t.row(["ShuffleNetV2 [16]", "70.8", "2.42", "AWS F1", "300", "274", "2746", "3321", "-"]);
    t.row(["RN50-W1A2 (paper)", "67.3", "18.3", "U250", "195", "1027", "3870", "2703", "1.9"]);

    let dev = device::alveo_u250();
    let net = resnet50(1);
    let r = network_resources(&net, &dev);
    let perf = sim::estimate(&net, 195.0);
    let bufs = memory::weight_buffers(&net, dev.slrs.len());
    // total BRAM: weights + CDC/stream FIFOs (activations live in URAM)
    let total_brams = memory::direct_brams(&bufs) + 2 * net.stages.len() as u64;
    t.row([
        "RN50-W1A2 (ours)".to_string(),
        format!("{:.1}", net.top1_pct),
        format!("{:.1}", perf.tops),
        "U250 model".to_string(),
        "195".to_string(),
        format!("{:.0}", r.luts / 1e3),
        format!("{total_brams}"),
        format!("{:.0}", perf.fps),
        format!("{:.1}", perf.latency_ms),
    ]);
    t
}

/// Fig. 4 — per-resblock LUT and BRAM utilization of RN50 (+ Fig. 5 SLR).
pub fn fig4() -> Table {
    let net = resnet50(1);
    let mut t = Table::new(["resblock", "kLUT", "BRAM18 (weights)", "SLR"]);
    let bufs = memory::weight_buffers(&net, 4);
    for stage in &net.stages {
        if let crate::nn::Stage::ResBlock { name, branch, bypass } = stage {
            let luts: f64 = branch
                .iter()
                .chain(bypass.iter())
                .map(|l| folding::layer_resources(l).luts)
                .sum::<f64>()
                + folding::cost::LUT_PER_RESBLOCK;
            let brams: u64 = branch
                .iter()
                .chain(bypass.iter())
                .map(|l| memory::WeightBuffer::from_layer(l, 0).brams())
                .sum();
            let slr = bufs
                .iter()
                .find(|b| b.layer.starts_with(name.as_str()))
                .map(|b| b.slr)
                .unwrap_or(0);
            t.row([
                name.clone(),
                format!("{:.1}", luts / 1e3),
                format!("{brams}"),
                format!("{slr}"),
            ]);
        }
    }
    t
}

/// Table IV — packed memory subsystems.
pub fn table4(generations: usize) -> Table {
    let mut t = Table::new([
        "accelerator", "logic kLUT", "BRAM18", "E %", "paper BRAM18", "paper E %",
    ]);
    let mut add =
        |name: &str, net: &Network, dev: &Device, hb: usize, paper_brams: &str, paper_e: &str| {
            let mut ga = default_ga_parallel(net, REPORT_ISLANDS, 0);
            ga.params.generations = generations;
            if hb == 0 {
                let bufs = memory::weight_buffers(net, dev.slrs.len());
                let brams = memory::direct_brams(&bufs);
                let eff = memory::efficiency(memory::total_bits(&bufs), brams);
                t.row([
                    name.to_string(),
                    "-".into(),
                    format!("{brams}"),
                    format!("{:.1}", 100.0 * eff),
                    paper_brams.to_string(),
                    paper_e.to_string(),
                ]);
            } else {
                let out = pack_network(net, dev, &ga, hb);
                t.row([
                    name.to_string(),
                    format!("{:.1}", out.logic_kluts),
                    format!("{}", out.report.brams),
                    format!("{:.1}", 100.0 * out.report.efficiency),
                    paper_brams.to_string(),
                    paper_e.to_string(),
                ]);
            }
        };
    let z = device::zynq_7020();
    let u250 = device::alveo_u250();
    let u280 = device::alveo_u280();
    let cnv1 = cnv(CnvVariant::W1A1);
    let cnv2 = cnv(CnvVariant::W2A2);
    let rn1 = resnet50(1);
    let rn2 = resnet50(2);
    add("CNV-W1A1", &cnv1, &z, 0, "126", "67.6");
    add("CNV-W1A1-P3", &cnv1, &z, 3, "108", "78.8");
    add("CNV-W1A1-P4", &cnv1, &z, 4, "96", "88.7");
    add("CNV-W2A2", &cnv2, &z, 0, "208", "79.9");
    add("CNV-W2A2-P3", &cnv2, &z, 3, "194", "85.6");
    add("CNV-W2A2-P4", &cnv2, &z, 4, "188", "88.4");
    add("RN50-W1A2-U250", &rn1, &u250, 0, "2320", "52.9");
    add("RN50-W1A2-U250-P3", &rn1, &u250, 3, "1804", "68.0");
    add("RN50-W1A2-U250-P4", &rn1, &u250, 4, "1632", "75.3");
    add("RN50-W1A2-U280-P4", &rn1, &u280, 4, "1327", "92.6");
    add("RN50-W2A2-U250-P4", &rn2, &u250, 4, "2642", "92.6");
    t
}

/// Table V — packed vs folded implementations.
pub fn table5(generations: usize) -> Table {
    let mut t = Table::new([
        "accelerator", "LUT %", "BRAM %", "Fc MHz", "Fm MHz", "dFPS %", "paper (Fc/Fm/dFPS)",
    ]);
    struct Row {
        name: &'static str,
        net: Network,
        dev: Device,
        hb: usize,
        folded: bool,
        paper: &'static str,
    }
    let rows = vec![
        Row {
            name: "CNV-W1A1-7020-P4",
            net: cnv(CnvVariant::W1A1),
            dev: device::zynq_7020(),
            hb: 4,
            folded: false,
            paper: "100/200/0",
        },
        Row {
            name: "CNV-W1A1-7012S-P4",
            net: cnv(CnvVariant::W1A1),
            dev: device::zynq_7012s(),
            hb: 4,
            folded: false,
            paper: "100/200/0",
        },
        Row {
            name: "RN50-W1A2-U250-P4",
            net: resnet50(1),
            dev: device::alveo_u250(),
            hb: 4,
            folded: false,
            paper: "183/363/12",
        },
        Row {
            name: "RN50-W1A2-U280-P4",
            net: resnet50(1),
            dev: device::alveo_u280(),
            hb: 4,
            folded: false,
            paper: "138/373/32",
        },
        Row {
            name: "RN50-W1A2-U280-F2",
            net: resnet50(1).fold2(),
            dev: device::alveo_u280(),
            hb: 0,
            folded: true,
            paper: "191/-/51",
        },
    ];
    for r in rows {
        let fc_target = r.dev.nominal_compute_mhz;
        let baseline = fc_target;
        let res = network_resources(&r.net, &r.dev);
        let (brams, logic_kluts, rf) = if r.hb > 0 {
            let mut ga = default_ga_parallel(&r.net, REPORT_ISLANDS, 0);
            ga.params.generations = generations;
            let out = pack_network(&r.net, &r.dev, &ga, r.hb);
            let fifo_brams = 2 * r.net.stages.len() as u64;
            (out.report.brams + fifo_brams, out.logic_kluts, r.hb as f64 / 2.0)
        } else {
            (res.total_brams(), 0.0, 1.0)
        };
        let lut_util =
            (res.luts + logic_kluts * 1e3 + r.dev.shell_luts as f64) / r.dev.luts as f64;
        let timing = timing::evaluate(&r.dev, lut_util, fc_target, rf, baseline);
        // folded designs do half the per-cycle work
        let delta = if r.folded {
            100.0 * (1.0 - timing.effective_fc_mhz / 2.0 / baseline)
        } else {
            timing.delta_fps_pct
        };
        t.row([
            r.name.to_string(),
            format!("{:.0}", 100.0 * lut_util),
            format!("{:.0}", 100.0 * brams as f64 / r.dev.bram18 as f64),
            format!("{:.0}", timing.fc_mhz),
            if rf > 1.0 { format!("{:.0}", timing.fm_mhz) } else { "-".into() },
            format!("{:.0}", delta),
            r.paper.to_string(),
        ]);
    }
    t
}

/// Sharding table — pipeline-parallel partitions of the paper's networks
/// over device fleets ([`crate::sharding`]): per-mix feasibility,
/// bottleneck FPS, shard OCM pressures and link utilization. CNV rows use
/// the GA engine at `generations`; RN50 rows use the FFD baseline
/// (`generations = 0`) to keep the `O(S²)` range sweep tractable.
pub fn shard_table(generations: usize) -> Table {
    use crate::sharding::{partition, Evaluator, PartitionConfig};
    let mut t = Table::new([
        "network", "devices", "k", "feasible", "FPS", "bottleneck", "max OCM %", "link %",
    ]);
    let mixes: Vec<(Network, Vec<Device>, usize)> = vec![
        (cnv(CnvVariant::W2A2), vec![device::zynq_7012s()], generations),
        (cnv(CnvVariant::W2A2), vec![device::zynq_7012s(), device::zynq_7012s()], generations),
        (cnv(CnvVariant::W2A2), vec![device::zynq_7020(), device::zynq_7012s()], generations),
        (resnet50(1), vec![device::alveo_u280()], 0),
        (resnet50(1), vec![device::alveo_u280(), device::alveo_u280()], 0),
        (resnet50(1), vec![device::alveo_u250(), device::alveo_u280()], 0),
    ];
    for (net, devs, gens) in mixes {
        let cfg = PartitionConfig { generations: gens, ..PartitionConfig::default() };
        let names: Vec<&str> = devs.iter().map(|d| d.name).collect();
        let k = devs.len();
        let (network, mix, kcol) = (net.name.clone(), names.join("+"), format!("{k}"));
        if k == 1 {
            let solo = Evaluator::new(&net, cfg).shard(0, net.stages.len(), &devs[0]);
            let (feasible, fps) = if solo.fits() {
                ("yes".to_string(), format!("{:.0}", 1.0 / solo.seconds_per_frame))
            } else {
                ("no".to_string(), "-".to_string())
            };
            t.row([
                network,
                mix,
                kcol,
                feasible,
                fps,
                "-".to_string(),
                format!("{:.0}", 100.0 * solo.bram_pressure()),
                "-".to_string(),
            ]);
            continue;
        }
        match partition(&net, &devs, cfg) {
            Err(_) => {
                let dash = || "-".to_string();
                t.row([network, mix, kcol, "no".into(), dash(), dash(), dash(), dash()])
            }
            Ok(plan) => {
                let max_ocm =
                    plan.shards.iter().map(|s| s.bram_pressure()).fold(0.0, f64::max);
                let max_link = plan.link_utilization().into_iter().fold(0.0, f64::max);
                let bottleneck = if plan.bottleneck_is_link() {
                    "link".to_string()
                } else {
                    let worst = plan
                        .shards
                        .iter()
                        .enumerate()
                        .max_by(|a, b| {
                            a.1.seconds_per_frame.partial_cmp(&b.1.seconds_per_frame).unwrap()
                        })
                        .map(|(i, _)| i)
                        .unwrap();
                    format!("shard{worst}")
                };
                t.row([
                    network,
                    mix,
                    kcol,
                    "yes".into(),
                    format!("{:.0}", plan.fps),
                    bottleneck,
                    format!("{:.0}", 100.0 * max_ocm),
                    format!("{:.0}", 100.0 * max_link),
                ]);
            }
        }
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_has_three_rows() {
        let t = table1();
        let s = t.render();
        assert!(s.contains("CNV-W1A1") && s.contains("CNV-W2A2"));
    }

    #[test]
    fn fig2_efficiency_decreases() {
        let t = fig2();
        let csv = t.to_csv();
        let effs: Vec<f64> = csv
            .lines()
            .skip(1)
            .map(|l| l.rsplit(',').next().unwrap().parse().unwrap())
            .collect();
        assert_eq!(effs.len(), 3);
        assert!(effs[0] > effs[1] && effs[1] > effs[2], "{effs:?}");
    }

    #[test]
    fn pack_network_cnv_p4_reduces_brams() {
        let net = cnv(CnvVariant::W1A1);
        let dev = device::zynq_7020();
        let mut ga = default_ga(&net);
        ga.params.generations = 30;
        let out = pack_network(&net, &dev, &ga, 4);
        assert!(out.report.brams < out.baseline_brams);
        assert!(out.report.efficiency > out.baseline_eff);
    }

    #[test]
    fn default_ga_parallel_is_thread_invariant() {
        // the report tables must print the same numbers on a laptop and a
        // 128-core box: worker count is an execution knob, not a parameter
        let net = cnv(CnvVariant::W1A1);
        let dev = device::zynq_7020();
        let mut a = default_ga_parallel(&net, REPORT_ISLANDS, 1);
        a.params.generations = 10;
        let mut b = default_ga_parallel(&net, REPORT_ISLANDS, 2);
        b.params.generations = 10;
        let oa = pack_network(&net, &dev, &a, 4);
        let ob = pack_network(&net, &dev, &b, 4);
        assert_eq!(oa.packing, ob.packing);
        assert_eq!(oa.report.brams, ob.report.brams);
    }
}
