//! Weight-buffer shape calculus and the physical OCM mapping (paper §II.B).
//!
//! A folded MVAU reads one `PE·SIMD·W`-bit word per compute cycle from a
//! buffer of depth `(K²·C_in/SIMD)·(C_out/PE)`; mapping those arbitrary
//! shapes onto fixed 18 Kib BRAM primitives wastes capacity — Eq. 1:
//! `E = N_p·W / (N_RAM · C_RAM)`. This module computes buffer shapes,
//! direct (unpacked) BRAM costs, the column slices the packing engines
//! operate on, and activation-storage estimates (URAM on Alveo).

use crate::device::bram::{brams_for, urams_for, BRAM18_BITS};
use crate::nn::{Layer, Network, Stage};

/// Maximum column width the packer slices buffers into: one BRAM18 port
/// word (36 bits, the widest primitive mode).
pub const COLUMN_WIDTH_BITS: u64 = 36;

/// One logical weight buffer (per MVAU), before physical mapping.
#[derive(Clone, Debug, PartialEq)]
pub struct WeightBuffer {
    pub layer: String,
    pub width_bits: u64,
    pub depth: u64,
    pub wbits: u64,
    /// SLR the owning MVAU is floorplanned to (Alveo; 0 on monolithic).
    pub slr: usize,
}

impl WeightBuffer {
    pub fn from_layer(l: &Layer, slr: usize) -> WeightBuffer {
        WeightBuffer {
            layer: l.name.clone(),
            width_bits: l.buffer_width_bits(),
            depth: l.buffer_depth(),
            wbits: l.wbits,
            slr,
        }
    }

    /// Payload bits stored in the buffer.
    pub fn bits(&self) -> u64 {
        self.width_bits * self.depth
    }

    /// Direct (unpacked) BRAM18 cost of this buffer.
    pub fn brams(&self) -> u64 {
        brams_for(self.width_bits, self.depth)
    }

    /// Slice into port-width columns — the items the packing engines place.
    /// A `w`-bit buffer becomes `ceil(w/36)` columns of depth `depth`; each
    /// column is an independently placeable stream slice.
    pub fn columns(&self, id_base: usize) -> Vec<PackItem> {
        let ncols = crate::util::ceil_div(self.width_bits, COLUMN_WIDTH_BITS);
        (0..ncols)
            .map(|c| {
                let w = if c == ncols - 1 {
                    self.width_bits - c * COLUMN_WIDTH_BITS
                } else {
                    COLUMN_WIDTH_BITS
                };
                PackItem {
                    id: id_base + c as usize,
                    layer: self.layer.clone(),
                    width_bits: w,
                    depth: self.depth,
                    slr: self.slr,
                    tenant: 0,
                }
            })
            .collect()
    }
}

/// A packable column slice (≤ 36 bits wide).
///
/// `tenant` tags which network of a co-packed catalog the slice belongs
/// to ([`crate::tenancy`]). Bins don't care where a column came from, so
/// every packing engine ignores the tag — single-tenant packings are
/// bit-identical whatever the tag says — and it exists purely so a
/// multi-network packing can be unpacked per tenant afterwards.
#[derive(Clone, Debug, PartialEq)]
pub struct PackItem {
    pub id: usize,
    pub layer: String,
    pub width_bits: u64,
    pub depth: u64,
    pub slr: usize,
    pub tenant: usize,
}

impl PackItem {
    pub fn bits(&self) -> u64 {
        self.width_bits * self.depth
    }

    /// BRAM cost if this item is placed alone.
    pub fn solo_brams(&self) -> u64 {
        brams_for(self.width_bits, self.depth)
    }
}

/// Eq. 1: physical RAM mapping efficiency.
pub fn efficiency(payload_bits: u64, n_brams: u64) -> f64 {
    if n_brams == 0 {
        return if payload_bits == 0 { 1.0 } else { 0.0 };
    }
    payload_bits as f64 / (n_brams * BRAM18_BITS) as f64
}

/// Weight buffers of a network's packable layers, with a simple SLR
/// assignment (Alveo floorplan, Fig. 5): stages are distributed over SLRs
/// in order, balanced by weight bits.
pub fn weight_buffers(net: &Network, n_slrs: usize) -> Vec<WeightBuffer> {
    let layers = net.packable_layers();
    let total_bits: u64 = layers.iter().map(|l| l.weight_bits()).sum();
    let per_slr = total_bits / n_slrs as u64 + 1;
    let mut out = Vec::new();
    let mut acc = 0u64;
    for l in layers {
        let slr = ((acc / per_slr) as usize).min(n_slrs - 1);
        out.push(WeightBuffer::from_layer(l, slr));
        acc += l.weight_bits();
    }
    out
}

/// Direct (unpacked) BRAM18 total for a set of buffers.
pub fn direct_brams(buffers: &[WeightBuffer]) -> u64 {
    buffers.iter().map(|b| b.brams()).sum()
}

/// Total payload bits of a set of buffers.
pub fn total_bits(buffers: &[WeightBuffer]) -> u64 {
    buffers.iter().map(|b| b.bits()).sum()
}

/// Column slices of all buffers, with globally unique ids.
pub fn all_columns(buffers: &[WeightBuffer]) -> Vec<PackItem> {
    let mut out = Vec::new();
    for b in buffers {
        let base = out.len();
        out.extend(b.columns(base));
    }
    out
}

/// Activation storage estimate (bits) for one stage: the sliding-window
/// line buffer (K rows of the input map) plus the stream FIFO; stored in
/// URAM on Alveo (paper §III.B) or BRAM on Zynq.
pub fn activation_bits(stage: &Stage) -> u64 {
    match stage {
        Stage::Mvau(l) => l.k * l.ifm * l.c_in * l.abits.max(1),
        Stage::MaxPool { window, ifm, channels, .. } => window * ifm * channels * 2,
        Stage::ResBlock { branch, .. } => {
            let line: u64 = branch.iter().map(|l| l.k * l.ifm * l.c_in * 4).sum();
            // deep bypass FIFO: must hold the branch latency worth of pixels
            // (paper §III.B "relatively deep FIFO on the bypass path")
            let l0 = &branch[0];
            let bypass_fifo = l0.ifm * l0.ifm * l0.c_in * 4 / 2;
            line + bypass_fifo
        }
    }
}

/// URAM blocks for a network's activation storage (Alveo style).
pub fn activation_urams(net: &Network) -> u64 {
    let bits: u64 = net.stages.iter().map(activation_bits).sum();
    // URAM fixed 72x4096 shape; activations are streamed 72-bit-wide
    urams_for(72, crate::util::ceil_div(bits, 72))
}

/// BRAM18 blocks for activation storage (Zynq style, no URAM), including
/// the inter-layer stream FIFOs HLS instantiates at each stage boundary.
pub fn activation_brams(net: &Network) -> u64 {
    let buffers: u64 = net
        .stages
        .iter()
        .map(|s| {
            let bits = activation_bits(s);
            brams_for(36, crate::util::ceil_div(bits, 36))
        })
        .sum();
    // stream FIFOs: ~4 BRAM18 per stage boundary (HLS instantiates
    // conservative depth-1024 FIFOs at each stream interface)
    buffers + 4 * net.stages.len() as u64
}

/// Paper-conclusion extension ("an alternative avenue for future work is to
/// extend the concepts presented here to ... activation storage"): expose
/// activation line buffers as pack items so the same FCMP engines can pack
/// them. Line buffers are read in a fixed schedule like weight buffers, so
/// the GALS port-multiplexing argument carries over.
pub fn activation_items(net: &Network, n_slrs: usize) -> Vec<PackItem> {
    let mut out = Vec::new();
    let per_slr = (net.stages.len() / n_slrs).max(1);
    for (si, stage) in net.stages.iter().enumerate() {
        for l in stage.layers() {
            if l.k <= 1 {
                continue; // no line buffer for pointwise/FC layers
            }
            // K-row line buffer: width = activation bits per pixel slice,
            // depth = ifm columns x K rows
            let width = (l.c_in * l.abits.max(1)).min(COLUMN_WIDTH_BITS);
            let depth = l.k * l.ifm;
            out.push(PackItem {
                id: out.len(),
                layer: format!("{}_swu", l.name),
                width_bits: width,
                depth,
                slr: (si / per_slr).min(n_slrs - 1),
                tenant: 0,
            });
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::{cnv, CnvVariant};

    fn buf(w: u64, d: u64) -> WeightBuffer {
        WeightBuffer { layer: "t".into(), width_bits: w, depth: d, wbits: 1, slr: 0 }
    }

    #[test]
    fn buffer_bits_conserved_by_slicing() {
        for (w, d) in [(48, 36), (1024, 36), (36, 512), (7, 100)] {
            let b = buf(w, d);
            let cols = b.columns(0);
            assert_eq!(cols.iter().map(|c| c.bits()).sum::<u64>(), b.bits());
            assert!(cols.iter().all(|c| c.width_bits <= COLUMN_WIDTH_BITS));
            assert_eq!(cols.len() as u64, crate::util::ceil_div(w, 36));
        }
    }

    #[test]
    fn efficiency_eq1() {
        // one full 36x512 BRAM: 18Kib payload / 18Kib capacity = 1.0
        assert!((efficiency(36 * 512, 1) - 1.0).abs() < 1e-12);
        assert!((efficiency(9216, 1) - 0.5).abs() < 1e-12);
        assert_eq!(efficiency(0, 0), 1.0);
        assert_eq!(efficiency(10, 0), 0.0);
    }

    #[test]
    fn cnv_baseline_efficiency_matches_table_iv_shape() {
        // Paper Table IV: CNV-W1A1 memory subsystem 126 BRAMs at E=67.6%.
        // Our mapper reproduces the same regime (~60-70%, ~120-145 BRAMs).
        let net = cnv(CnvVariant::W1A1);
        let bufs = weight_buffers(&net, 1);
        let brams = direct_brams(&bufs);
        let e = efficiency(total_bits(&bufs), brams);
        assert!((100..160).contains(&(brams as i64)), "brams {brams}");
        assert!(e > 0.5 && e < 0.8, "E {e}");
    }

    #[test]
    fn w2a2_baseline_more_efficient_than_w1a1() {
        // Table IV: CNV-W2A2 baseline E (79.9%) > CNV-W1A1 baseline (67.6%)
        let e1 = {
            let b = weight_buffers(&cnv(CnvVariant::W1A1), 1);
            efficiency(total_bits(&b), direct_brams(&b))
        };
        let e2 = {
            let b = weight_buffers(&cnv(CnvVariant::W2A2), 1);
            efficiency(total_bits(&b), direct_brams(&b))
        };
        assert!(e2 > e1, "E(W2A2) {e2} vs E(W1A1) {e1}");
    }

    #[test]
    fn slr_assignment_is_balanced_and_ordered() {
        let net = crate::nn::resnet50(1);
        let bufs = weight_buffers(&net, 4);
        // monotone nondecreasing SLR along the pipeline (daisy-chain, Fig 5)
        assert!(bufs.windows(2).all(|w| w[0].slr <= w[1].slr));
        let mut bits = [0u64; 4];
        for b in &bufs {
            bits[b.slr] += b.bits();
        }
        let max = *bits.iter().max().unwrap() as f64;
        let min = *bits.iter().min().unwrap() as f64;
        assert!(min / max > 0.3, "imbalance {bits:?}");
    }

    #[test]
    fn activation_items_pack_with_the_same_engines() {
        // future-work extension: activation line buffers through FCMP
        let net = crate::nn::resnet50(1);
        let items = activation_items(&net, 4);
        assert!(!items.is_empty());
        let c = crate::packing::Constraints::new(4, true);
        let (p, r) = crate::packing::run_packer(
            &crate::packing::ffd::Ffd::new(),
            &items,
            &c,
        );
        p.validate(&items, &c).unwrap();
        let solo: u64 = items.iter().map(|i| i.solo_brams()).sum();
        assert!(r.brams <= solo);
        // shallow line buffers coalesce dramatically
        assert!(r.efficiency > 2.0 * efficiency(items.iter().map(|i| i.bits()).sum(), solo));
    }

    #[test]
    fn activation_storage_positive_and_bounded() {
        let net = cnv(CnvVariant::W1A1);
        let brams = activation_brams(&net);
        assert!(brams > 0 && brams < 200, "activation brams {brams}");
    }
}
