//! Cycle-level simulator of the GALS weight-streamer (paper §IV, Figs 6/7).
//!
//! A packed BRAM holds `N_b` co-located weight buffers read through the two
//! physical ports in round-robin; the memory domain runs at
//! `R_F = F_mem / F_comp` times the compute clock, so the compute side
//! perceives `2·R_F` virtual ports (Eq. 2). Words cross the clock-domain
//! boundary through per-stream asynchronous FIFOs; the compute side consumes
//! one word per stream per compute cycle.
//!
//! Two configurations, matching Fig. 7:
//! * **7a** — even `N_b`, integer `R_F`: half the streams on port A, half on
//!   port B; each stream is read `2·R_F/N_b` times per compute cycle.
//! * **7b** — odd `N_b`, fractional `R_F = N_b/2`: one buffer is split into
//!   ODD/EVEN address sub-buffers served by *different* ports and re-merged
//!   by a data-width converter (DWC); the split stream would get
//!   `2·N_b/(N_b+1) > 1` words per compute cycle, so the compute side
//!   backpressures it and an *adaptive* streamer redistributes the unused
//!   slots to the other streams — a static streamer wastes them.
//!
//! The simulator advances a base clock of `lcm(mem, comp)` phases and
//! reproduces these rates cycle-exactly, including FIFO occupancy and
//! backpressure; tests assert the paper's closed-form rates.

use crate::util::rng::Rng;

/// Frequency ratio `R_F = F_mem / F_comp` as an exact rational num/den.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Ratio {
    pub num: u64,
    pub den: u64,
}

impl Ratio {
    pub fn new(num: u64, den: u64) -> Ratio {
        assert!(num > 0 && den > 0 && num >= den, "R_F must be >= 1");
        Ratio { num, den }
    }

    pub fn as_f64(self) -> f64 {
        self.num as f64 / self.den as f64
    }

    /// `R_F = 2` (Fig. 7a with N_b = 4).
    pub fn two() -> Ratio {
        Ratio::new(2, 1)
    }

    /// `R_F = 1.5` (Fig. 7b with N_b = 3).
    pub fn three_halves() -> Ratio {
        Ratio::new(3, 2)
    }
}

fn gcd(a: u64, b: u64) -> u64 {
    if b == 0 { a } else { gcd(b, a % b) }
}

/// Streamer configuration.
#[derive(Clone, Debug)]
pub struct StreamerConfig {
    /// Words per logical buffer (readback wraps around — continuous frames).
    pub buffer_depths: Vec<u64>,
    /// Memory/compute frequency ratio.
    pub rf: Ratio,
    /// Per-stream async FIFO depth (words).
    pub fifo_depth: usize,
    /// Index of the buffer split ODD/EVEN across both ports (Fig. 7b).
    pub split: Option<usize>,
    /// Adaptive read-slot reallocation under backpressure (Fig. 7b text).
    pub adaptive: bool,
}

impl StreamerConfig {
    /// Fig. 7a: `n` equal buffers, integer ratio, no split.
    pub fn fig7a(n: usize, depth: u64, rf: Ratio) -> StreamerConfig {
        StreamerConfig {
            buffer_depths: vec![depth; n],
            rf,
            fifo_depth: 8,
            split: None,
            adaptive: true,
        }
    }

    /// Fig. 7b: `n` (odd) equal buffers, `R_F = n/2`, buffer 0 split.
    pub fn fig7b(n: usize, depth: u64) -> StreamerConfig {
        assert!(n % 2 == 1, "fig7b wants odd N_b");
        StreamerConfig {
            buffer_depths: vec![depth; n],
            rf: Ratio::new(n as u64, 2),
            fifo_depth: 8,
            split: Some(0),
            adaptive: true,
        }
    }
}

/// Per-stream results.
#[derive(Clone, Debug)]
pub struct StreamStats {
    /// Words delivered to the compute domain.
    pub words: u64,
    /// Compute cycles in which this stream had no word available (stall).
    pub stalls: u64,
    /// Achieved rate in words per compute cycle.
    pub rate: f64,
}

/// Whole-run results.
#[derive(Clone, Debug)]
pub struct SimResult {
    pub per_stream: Vec<StreamStats>,
    pub compute_cycles: u64,
    pub memory_cycles: u64,
    /// Port read slots that went unused (idle or blocked by full FIFOs).
    pub wasted_slots: u64,
}

impl SimResult {
    /// Minimum achieved rate over streams — ≥ 1.0 means full throughput
    /// (every MVAU gets its weight word every compute cycle).
    pub fn min_rate(&self) -> f64 {
        self.per_stream.iter().map(|s| s.rate).fold(f64::INFINITY, f64::min)
    }
}

/// One port-multiplexed packed-BRAM weight streamer.
///
/// Sub-streams: each logical buffer is one stream, except the split buffer
/// which becomes two sub-streams (ODD/EVEN) merged by the DWC at the
/// consumer; consumption alternates EVEN, ODD, EVEN, ... (address order).
pub struct StreamerSim {
    cfg: StreamerConfig,
    /// sub-stream -> owning logical stream
    owner: Vec<usize>,
    /// sub-stream -> serving port (0 = A, 1 = B)
    port: Vec<usize>,
    /// FIFO occupancy per sub-stream
    fifo: Vec<usize>,
    /// read pointer per sub-stream (wraps at its depth)
    rdptr: Vec<u64>,
    /// round-robin pointer per port
    rr: [usize; 2],
    /// DWC phase for the split stream (0 = EVEN next, 1 = ODD next)
    dwc_phase: usize,
    /// Precomputed sub-streams per port (hot loop: no per-cycle allocation).
    port_streams: [Vec<usize>; 2],
    /// Precomputed logical stream -> (first sub, second sub or usize::MAX).
    subs: Vec<(usize, usize)>,
}

impl StreamerSim {
    pub fn new(cfg: StreamerConfig) -> StreamerSim {
        let n = cfg.buffer_depths.len();
        assert!(n >= 1);
        let mut owner = Vec::new();
        let mut port = Vec::new();
        match cfg.split {
            None => {
                // Fig 7a: alternate streams across ports
                for s in 0..n {
                    owner.push(s);
                    port.push(s % 2);
                }
            }
            Some(sp) => {
                assert!(sp < n, "split index in range");
                // split stream contributes EVEN on port A and ODD on port B;
                // remaining streams alternate starting opposite the split
                for s in 0..n {
                    if s == sp {
                        owner.push(s); // EVEN half
                        port.push(0);
                        owner.push(s); // ODD half
                        port.push(1);
                    } else {
                        owner.push(s);
                        port.push((s + 1) % 2);
                    }
                }
            }
        }
        let m = owner.len();
        let port_streams = [
            (0..m).filter(|&s| port[s] == 0).collect::<Vec<_>>(),
            (0..m).filter(|&s| port[s] == 1).collect::<Vec<_>>(),
        ];
        let mut subs = vec![(usize::MAX, usize::MAX); n];
        for (sub, &o) in owner.iter().enumerate() {
            if subs[o].0 == usize::MAX {
                subs[o].0 = sub;
            } else {
                subs[o].1 = sub;
            }
        }
        StreamerSim {
            cfg,
            owner,
            port,
            fifo: vec![0; m],
            rdptr: vec![0; m],
            rr: [0, 0],
            dwc_phase: 0,
            port_streams,
            subs,
        }
    }

    /// Run for `compute_cycles` compute-domain cycles; returns rates.
    pub fn run(&mut self, compute_cycles: u64) -> SimResult {
        let n_logical = self.cfg.buffer_depths.len();
        let rf = self.cfg.rf;
        // base clock: comp edge every `num` phases, mem edge every `den`
        let g = gcd(rf.num, rf.den);
        let (comp_period, mem_period) = (rf.num / g, rf.den / g);
        let total_phases = compute_cycles * comp_period;

        let mut words = vec![0u64; n_logical];
        let mut stalls = vec![0u64; n_logical];
        let mut mem_cycles = 0u64;
        let mut wasted = 0u64;

        for phase in 0..total_phases {
            // memory-domain edge: both ports issue one read each
            if phase % mem_period == 0 {
                mem_cycles += 1;
                for p in 0..2usize {
                    let on_port = &self.port_streams[p];
                    let len = on_port.len();
                    let mut served = false;
                    if len > 0 {
                        let start = self.rr[p] % len;
                        for k in 0..len {
                            let s = on_port[(start + k) % len];
                            if self.fifo[s] < self.cfg.fifo_depth {
                                self.fifo[s] += 1;
                                let depth = self.cfg.buffer_depths[self.owner[s]];
                                self.rdptr[s] = (self.rdptr[s] + 1) % depth.max(1);
                                self.rr[p] = (start + k + 1) % len;
                                served = true;
                                break;
                            }
                            if !self.cfg.adaptive {
                                // static streamer: the scheduled slot is lost
                                self.rr[p] = (start + 1) % len;
                                break;
                            }
                        }
                    }
                    if !served {
                        wasted += 1;
                    }
                }
            }
            // compute-domain edge: each logical stream consumes one word
            if phase % comp_period == 0 {
                for s in 0..n_logical {
                    if Some(s) == self.cfg.split {
                        // DWC: alternate EVEN/ODD halves
                        let (even, odd) = self.subs[s];
                        let want = if self.dwc_phase == 0 { even } else { odd };
                        if self.fifo[want] > 0 {
                            self.fifo[want] -= 1;
                            words[s] += 1;
                            self.dwc_phase ^= 1;
                        } else {
                            stalls[s] += 1;
                        }
                    } else {
                        let sub = self.subs[s].0;
                        if self.fifo[sub] > 0 {
                            self.fifo[sub] -= 1;
                            words[s] += 1;
                        } else {
                            stalls[s] += 1;
                        }
                    }
                }
            }
        }

        SimResult {
            per_stream: (0..n_logical)
                .map(|s| StreamStats {
                    words: words[s],
                    stalls: stalls[s],
                    rate: words[s] as f64 / compute_cycles as f64,
                })
                .collect(),
            compute_cycles,
            memory_cycles: mem_cycles,
            wasted_slots: wasted,
        }
    }
}

/// LUT overhead model for a packed memory subsystem (Table IV "Logic"
/// column): per-stream CDC FIFO + streamer address/mux logic, plus the DWC
/// for split streams. Calibrated against Table IV (CNV ~4-7 kLUT for ~300
/// streams, RN50 ~39-66 kLUT for thousands of streams).
pub fn streamer_lut_overhead(n_streams: usize, n_bins: usize, with_dwc: usize) -> f64 {
    const LUT_PER_STREAM_FIFO: f64 = 18.0; // async FIFO + CDC sync flops
    const LUT_PER_BIN_MUX: f64 = 22.0; // round-robin port mux + addressing
    const LUT_PER_DWC: f64 = 40.0; // odd/even data-width converter
    n_streams as f64 * LUT_PER_STREAM_FIFO
        + n_bins as f64 * LUT_PER_BIN_MUX
        + with_dwc as f64 * LUT_PER_DWC
}

/// Randomized mixed-traffic experiment: unequal depths at a given H_B and
/// R_F. Used by property tests and the `gals_throughput` bench.
pub fn random_config(rng: &mut Rng, nb: usize, rf: Ratio) -> StreamerConfig {
    StreamerConfig {
        buffer_depths: (0..nb).map(|_| 16 + rng.below(512)).collect(),
        rf,
        fifo_depth: 4 + rng.below(12) as usize,
        split: None,
        adaptive: true,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const CYCLES: u64 = 4_000;
    const TOL: f64 = 0.02;

    fn approx(a: f64, b: f64) -> bool {
        (a - b).abs() <= TOL * b.max(1e-9)
    }

    #[test]
    fn fig7a_four_buffers_rf2_full_throughput() {
        // N_b = 4, R_F = 2: each stream read 2*R_F/N_b = 1.0 / compute cycle
        let mut sim = StreamerSim::new(StreamerConfig::fig7a(4, 128, Ratio::two()));
        let r = sim.run(CYCLES);
        for s in &r.per_stream {
            assert!(approx(s.rate, 1.0), "rate {}", s.rate);
        }
        assert!(r.min_rate() >= 1.0 - TOL);
    }

    #[test]
    fn fig7a_four_buffers_rf1_half_throughput() {
        // R_F = 1 with 4 buffers on 2 ports: 2*1/4 = 0.5 words/cycle
        let mut sim = StreamerSim::new(StreamerConfig::fig7a(4, 128, Ratio::new(1, 1)));
        let r = sim.run(CYCLES);
        for s in &r.per_stream {
            assert!(approx(s.rate, 0.5), "rate {}", s.rate);
        }
    }

    #[test]
    fn fig7a_two_buffers_rf1_is_classic_dual_port() {
        // 2 buffers, 2 ports, same clock: each gets 1.0 (no FCMP needed)
        let mut sim = StreamerSim::new(StreamerConfig::fig7a(2, 64, Ratio::new(1, 1)));
        let r = sim.run(CYCLES);
        assert!(r.min_rate() >= 1.0 - TOL);
    }

    #[test]
    fn eq2_boundary_height_equals_two_rf() {
        // H_B = 2*R_F exactly sustains rate 1.0; H_B = 2*R_F + 2 cannot
        for (nb, rf) in [(4usize, Ratio::two()), (6, Ratio::new(3, 1)), (2, Ratio::new(1, 1))] {
            let mut sim = StreamerSim::new(StreamerConfig::fig7a(nb, 96, rf));
            assert!(
                sim.run(CYCLES).min_rate() >= 1.0 - TOL,
                "H_B = 2R_F must sustain (nb={nb})"
            );
            let mut over = StreamerSim::new(StreamerConfig::fig7a(nb + 2, 96, rf));
            let r = over.run(CYCLES);
            assert!(
                r.min_rate() < 1.0 - TOL,
                "H_B > 2R_F must lose throughput (nb={})",
                nb + 2
            );
        }
    }

    #[test]
    fn fig7b_three_buffers_rf_1_5_adaptive_full_throughput() {
        // N_b = 3, R_F = 1.5, buffer 0 split ODD/EVEN: the split stream is
        // offered 2*N_b/(N_b+1) = 1.5 > 1, compute backpressures it, and the
        // adaptive streamer redistributes slots so ALL streams sustain 1.0
        let mut sim = StreamerSim::new(StreamerConfig::fig7b(3, 120));
        let r = sim.run(CYCLES);
        for (i, s) in r.per_stream.iter().enumerate() {
            assert!(approx(s.rate, 1.0), "stream {i} rate {}", s.rate);
        }
    }

    #[test]
    fn fig7b_static_streamer_loses_throughput() {
        let mut cfg = StreamerConfig::fig7b(3, 120);
        cfg.adaptive = false;
        let mut sim = StreamerSim::new(cfg);
        let r = sim.run(CYCLES);
        // without slot reallocation the non-split streams only get
        // 2*R_F/(N_b+1) = 0.75 words per compute cycle
        assert!(r.min_rate() < 0.87, "static min rate {}", r.min_rate());
    }

    #[test]
    fn fig7b_five_buffers_rf_2_5() {
        let mut sim = StreamerSim::new(StreamerConfig::fig7b(5, 200));
        let r = sim.run(CYCLES);
        for s in &r.per_stream {
            assert!(approx(s.rate, 1.0), "rate {}", s.rate);
        }
    }

    #[test]
    fn deeper_fifo_never_hurts() {
        let mut shallow = StreamerConfig::fig7a(4, 77, Ratio::two());
        shallow.fifo_depth = 2;
        let mut deep = shallow.clone();
        deep.fifo_depth = 32;
        let rs = StreamerSim::new(shallow).run(CYCLES).min_rate();
        let rd = StreamerSim::new(deep).run(CYCLES).min_rate();
        assert!(rd >= rs - TOL, "deep {rd} vs shallow {rs}");
    }

    #[test]
    fn rates_never_exceed_one() {
        // compute consumes at most one word per stream per cycle
        for nb in [2usize, 3, 4] {
            let cfg = if nb % 2 == 0 {
                StreamerConfig::fig7a(nb, 64, Ratio::new(4, 1))
            } else {
                StreamerConfig::fig7b(nb, 64)
            };
            let r = StreamerSim::new(cfg).run(CYCLES);
            for s in &r.per_stream {
                assert!(s.rate <= 1.0 + 1e-9);
            }
        }
    }

    #[test]
    fn conservation_words_plus_stalls() {
        let r = StreamerSim::new(StreamerConfig::fig7a(6, 50, Ratio::two())).run(CYCLES);
        for s in &r.per_stream {
            assert_eq!(s.words + s.stalls, CYCLES);
        }
    }

    #[test]
    fn lut_overhead_scales_with_streams() {
        let small = streamer_lut_overhead(300, 100, 0);
        let big = streamer_lut_overhead(3000, 1400, 60);
        assert!(small > 3_000.0 && small < 10_000.0, "CNV-class {small}");
        assert!(big > 30_000.0 && big < 100_000.0, "RN50-class {big}");
    }
}
