//! Minimal TOML-subset configuration parser (serde/toml unavailable
//! offline — DESIGN.md substitutions). Supports `[table]` headers, string /
//! integer / float / boolean scalars, flat arrays, comments and blank lines
//! — enough for experiment configuration files.

use std::collections::BTreeMap;

/// A parsed scalar value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_float(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// A parsed config: `table.key -> value` (root table has empty name).
#[derive(Clone, Debug, Default)]
pub struct Config {
    values: BTreeMap<String, Value>,
}

/// Parse error with line information.
#[derive(Debug, PartialEq)]
pub struct ParseError {
    pub line: usize,
    pub message: String,
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "config parse error on line {}: {}", self.line, self.message)
    }
}

impl std::error::Error for ParseError {}

fn parse_scalar(s: &str, line: usize) -> Result<Value, ParseError> {
    let s = s.trim();
    let err = |m: &str| ParseError { line, message: m.to_string() };
    if s.is_empty() {
        return Err(err("empty value"));
    }
    if let Some(rest) = s.strip_prefix('"') {
        let inner = rest.strip_suffix('"').ok_or_else(|| err("unterminated string"))?;
        return Ok(Value::Str(inner.to_string()));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if s.starts_with('[') {
        let inner = s
            .strip_prefix('[')
            .and_then(|x| x.strip_suffix(']'))
            .ok_or_else(|| err("unterminated array"))?;
        let mut vals = Vec::new();
        let trimmed = inner.trim();
        if !trimmed.is_empty() {
            for part in trimmed.split(',') {
                vals.push(parse_scalar(part, line)?);
            }
        }
        return Ok(Value::Array(vals));
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(err(&format!("cannot parse value {s:?}")))
}

impl Config {
    /// Parse TOML-subset text.
    pub fn parse(text: &str) -> Result<Config, ParseError> {
        let mut cfg = Config::default();
        let mut table = String::new();
        for (idx, raw) in text.lines().enumerate() {
            let line_no = idx + 1;
            let line = match raw.find('#') {
                // a # inside a quoted string is kept
                Some(pos) if !raw[..pos].contains('"') => &raw[..pos],
                _ => raw,
            }
            .trim();
            if line.is_empty() {
                continue;
            }
            if let Some(h) = line.strip_prefix('[') {
                let name = h.strip_suffix(']').ok_or(ParseError {
                    line: line_no,
                    message: "unterminated table header".into(),
                })?;
                table = name.trim().to_string();
                continue;
            }
            let (k, v) = line.split_once('=').ok_or(ParseError {
                line: line_no,
                message: format!("expected key = value, got {line:?}"),
            })?;
            let key = if table.is_empty() {
                k.trim().to_string()
            } else {
                format!("{table}.{}", k.trim())
            };
            let value = parse_scalar(v, line_no)?;
            cfg.values.insert(key, value);
        }
        Ok(cfg)
    }

    /// Load from a file.
    pub fn load(path: &std::path::Path) -> crate::Result<Config> {
        let text = std::fs::read_to_string(path)?;
        Ok(Config::parse(&text).map_err(|e| anyhow::anyhow!("{path:?}: {e}"))?)
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.values.get(key)
    }

    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).and_then(Value::as_str).unwrap_or(default)
    }

    pub fn int_or(&self, key: &str, default: i64) -> i64 {
        self.get(key).and_then(Value::as_int).unwrap_or(default)
    }

    pub fn float_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(Value::as_float).unwrap_or(default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(Value::as_bool).unwrap_or(default)
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.values.keys().map(|s| s.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# experiment configuration
name = "rn50-u250-p4"
seed = 2020

[packing]
engine = "ga"
bin_height = 4
population = 75
p_mut = 0.4
same_slr = true
depths = [36, 72, 144]

[timing]
fc_target = 200.0
"#;

    #[test]
    fn parses_sample() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.str_or("name", ""), "rn50-u250-p4");
        assert_eq!(c.int_or("seed", 0), 2020);
        assert_eq!(c.str_or("packing.engine", ""), "ga");
        assert_eq!(c.int_or("packing.bin_height", 0), 4);
        assert_eq!(c.float_or("packing.p_mut", 0.0), 0.4);
        assert!(c.bool_or("packing.same_slr", false));
        assert_eq!(c.float_or("timing.fc_target", 0.0), 200.0);
    }

    #[test]
    fn arrays() {
        let c = Config::parse(SAMPLE).unwrap();
        match c.get("packing.depths") {
            Some(Value::Array(v)) => {
                assert_eq!(v, &vec![Value::Int(36), Value::Int(72), Value::Int(144)]);
            }
            other => panic!("bad array: {other:?}"),
        }
    }

    #[test]
    fn comments_and_blank_lines_ignored() {
        let c = Config::parse("# only a comment\n\nx = 1 # trailing\n").unwrap();
        assert_eq!(c.int_or("x", 0), 1);
    }

    #[test]
    fn string_with_hash_preserved() {
        let c = Config::parse("label = \"a#b\"\n").unwrap();
        assert_eq!(c.str_or("label", ""), "a#b");
    }

    #[test]
    fn error_reports_line() {
        let e = Config::parse("ok = 1\nbroken\n").unwrap_err();
        assert_eq!(e.line, 2);
    }

    #[test]
    fn bad_value_is_error() {
        assert!(Config::parse("x = nope\n").is_err());
        assert!(Config::parse("x = \"unterminated\n").is_err());
        assert!(Config::parse("[unclosed\n").is_err());
    }

    #[test]
    fn ints_floats_bools() {
        let c = Config::parse("a = -3\nb = 2.5\nc = false\n").unwrap();
        assert_eq!(c.int_or("a", 0), -3);
        assert_eq!(c.float_or("b", 0.0), 2.5);
        assert!(!c.bool_or("c", true));
        // int usable as float
        assert_eq!(c.float_or("a", 0.0), -3.0);
    }

    #[test]
    fn empty_array() {
        let c = Config::parse("xs = []\n").unwrap();
        assert_eq!(c.get("xs"), Some(&Value::Array(vec![])));
    }
}
