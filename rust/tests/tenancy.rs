//! Multi-tenant model zoo: co-packing invariants, the consolidation
//! witness, and the tenant-aware serving path.
//!
//! Four suites:
//!
//! * **Co-packing properties**: over random two-tenant MLP catalogs the
//!   shared packing stays structurally valid (height / SLR caps, every
//!   item placed exactly once), per-tenant unpack returns exactly that
//!   tenant's input items, pro-rata BRAM attribution sums to the packed
//!   total, and the tenant tag never perturbs the engine — single-tenant
//!   packings are bit-identical to the pre-tenancy packer.
//! * **Consolidation witness**: CNV-W2A2 + SFC co-pack onto one Zynq
//!   7020 where the unpacked catalog overflows it and a dedicated
//!   per-tenant fleet needs two boards.
//! * **Differential**: tagged replay of a merged two-tenant trace
//!   through the thread-backed server and the DES must agree exactly on
//!   per-tenant accepted/shed/deadline-shed and (round-robin) per-group
//!   dispatch counts in a no-overload configuration.
//! * **Admission**: both tenants meet their p99 SLO under a merged
//!   diurnal trace, and under a per-tenant flash crowd the
//!   deadline-aware arm yields strictly higher goodput than the FIFO
//!   baseline while the healthy tenant's trajectory is untouched.

use std::time::Duration;

use fcmp::coordinator::{
    diurnal, flash_crowd, poisson, BatcherConfig, ChainGroup, Deployment, FleetSummary,
    MockBackend, Policy, Server, Trace,
};
use fcmp::device::{zynq_7012s, zynq_7020};
use fcmp::memory::{all_columns, weight_buffers};
use fcmp::nn::{cnv, mlp, sfc_w1a1, CnvVariant, Network};
use fcmp::packing::{ffd::Ffd, run_packer, Constraints, Packing};
use fcmp::sim::{FleetSim, SimBackend, SimConfig, SimReport};
use fcmp::tenancy::{co_pack, dedicated_devices};
use fcmp::util::prop::{check, Shrink};
use fcmp::util::rng::Rng;

// ---------------------------------------------------------------- packing

/// A random two-tenant MLP catalog plus the bin-height constraint it is
/// packed under: `(hidden, wbits, pe, simd)` per tenant.
#[derive(Clone, Debug)]
struct ZooCase {
    specs: Vec<(u64, u64, u64, u64)>,
    hb: usize,
}

impl Shrink for ZooCase {
    fn shrink(&self) -> Vec<ZooCase> {
        if self.specs.len() > 1 {
            self.specs
                .iter()
                .map(|s| ZooCase { specs: vec![*s], hb: self.hb })
                .collect()
        } else {
            Vec::new()
        }
    }
}

fn gen_case(rng: &mut Rng) -> ZooCase {
    let spec = |rng: &mut Rng| {
        let hidden = [64u64, 128, 192, 256][rng.range(0, 4)];
        let wbits = 1 + rng.below(2);
        let pe = [4u64, 8, 16, 32][rng.range(0, 4)];
        let simd = [4u64, 8, 16, 32][rng.range(0, 4)];
        (hidden, wbits, pe, simd)
    };
    ZooCase { specs: vec![spec(rng), spec(rng)], hb: 2 + rng.range(0, 3) }
}

fn case_nets(case: &ZooCase) -> Vec<Network> {
    case.specs
        .iter()
        .enumerate()
        .map(|(i, &(h, w, pe, simd))| mlp(&format!("zoo-t{i}"), h, w, w, pe, simd))
        .collect()
}

#[test]
fn prop_copack_valid_unpack_exact_tag_invariant() {
    check(42, 30, gen_case, |case| {
        let nets = case_nets(case);
        let refs: Vec<&Network> = nets.iter().collect();
        let dev = zynq_7020();
        let cp = co_pack(&refs, &dev, case.hb, 0, 11);
        let c = Constraints::new(case.hb, false);
        cp.packing.validate(&cp.items, &c).map_err(|e| format!("invalid packing: {e}"))?;

        // never worse than placing every column alone (the device-cap
        // soundness bound: packing can only reduce BRAM demand)
        let single = Packing::singletons(cp.items.len()).total_brams(&cp.items);
        if cp.weight_brams > single {
            return Err(format!("packed {} > singleton {}", cp.weight_brams, single));
        }

        // per-tenant unpack returns exactly that tenant's input items,
        // and the tenants partition the catalog
        let mut all: Vec<usize> = Vec::new();
        for t in 0..refs.len() {
            let ids = cp.unpack_tenant(t);
            let expect: Vec<usize> =
                cp.items.iter().filter(|i| i.tenant == t).map(|i| i.id).collect();
            if ids != expect {
                return Err(format!("tenant {t} unpack {ids:?} != input {expect:?}"));
            }
            all.extend(ids);
        }
        all.sort_unstable();
        if all != (0..cp.items.len()).collect::<Vec<_>>() {
            return Err("tenant unpacks do not partition the item set".into());
        }

        // pro-rata attribution sums back to the packed total
        let sum: f64 = (0..refs.len()).map(|t| cp.tenant_brams(t)).sum();
        if (sum - cp.weight_brams as f64).abs() > 1e-6 {
            return Err(format!("attribution {sum} != packed {}", cp.weight_brams));
        }

        // the tenant tag never perturbs the engine: retagging every item
        // to tenant 0 repacks to bit-identical bins
        let mut retag = cp.items.clone();
        for it in &mut retag {
            it.tenant = 0;
        }
        let (repacked, _) = run_packer(&Ffd::new(), &retag, &c);
        if repacked != cp.packing {
            return Err("retagged catalog packed differently".into());
        }

        // single-tenant co-pack is bit-identical to the pre-tenancy
        // packer fed the network's raw column slices
        let solo = co_pack(&[&nets[0]], &dev, case.hb, 0, 11);
        let cols = all_columns(&weight_buffers(&nets[0], dev.slrs.len()));
        if cols != solo.items {
            return Err("single-tenant catalog items diverge from all_columns".into());
        }
        let (legacy, _) = run_packer(&Ffd::new(), &cols, &c);
        if legacy != solo.packing {
            return Err("single-tenant packing not bit-identical to pre-tenancy".into());
        }
        Ok(())
    });
}

#[test]
fn co_packed_catalog_consolidates_two_boards_into_one() {
    // the feasibility witness: CNV-W2A2 + SFC share one 7020 co-packed
    // (~260/280 BRAM18), overflow it unpacked (~309), and a dedicated
    // per-tenant fleet needs a board each — FFD already consolidates and
    // the FFD-seeded GA can only improve on it
    let cnv22 = cnv(CnvVariant::W2A2);
    let sfc = sfc_w1a1();
    let nets = [&cnv22, &sfc];
    let dev = zynq_7020();
    for generations in [0, 40] {
        let cp = co_pack(&nets, &dev, 4, generations, 7);
        assert!(
            cp.fits(),
            "co-packed catalog overflows ({} > {} BRAM18, generations {generations})",
            cp.total_brams(),
            cp.device_brams
        );
        assert!(
            !cp.fits_direct(),
            "unpacked catalog must overflow ({} <= {} BRAM18)",
            cp.total_direct_brams(),
            cp.device_brams
        );
        assert_eq!(
            dedicated_devices(&nets, &dev, 4, generations, 7),
            2,
            "dedicated per-tenant packing must need one board per tenant"
        );
    }
}

#[test]
fn second_tenant_overflows_the_paper_port_device() {
    // CNV-W1A1 packed fits the 7012S (the paper's §V porting point) but
    // the embedded part has no headroom for even the small MLP tenant —
    // consolidation needs the 7020-class device the witness uses
    let cnv11 = cnv(CnvVariant::W1A1);
    let sfc = sfc_w1a1();
    let dev = zynq_7012s();
    let solo = co_pack(&[&cnv11], &dev, 4, 0, 7);
    assert!(solo.fits(), "CNV-W1A1 packed must fit one 7012S ({})", solo.total_brams());
    let pair = co_pack(&[&cnv11, &sfc], &dev, 4, 0, 7);
    assert!(!pair.fits(), "7012S must lack headroom for a second tenant");
}

// ---------------------------------------------------------------- serving

fn two_tenant_plan(chains_per_tenant: usize, queue: usize) -> Deployment {
    let mut groups = Vec::new();
    for t in 0..2 {
        for _ in 0..chains_per_tenant {
            groups.push(ChainGroup::new(1).for_tenant(t));
        }
    }
    Deployment { groups, ..Deployment::default() }
        .with_policy(Policy::RoundRobin)
        .with_batcher(BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(1) })
        .with_queue_depth(queue)
        .with_window(2)
}

fn merged_two_tenant(n: usize, rate: f64, seed: u64) -> (Trace, Vec<usize>) {
    let t0 = poisson(n, rate, seed);
    let t1 = poisson(n, rate, seed + 1);
    Trace::merge(&[(0, &t0), (1, &t1)])
}

fn per_tenant_counts(s: &FleetSummary) -> Vec<(usize, usize, usize, usize)> {
    s.per_tenant
        .iter()
        .map(|t| (t.submitted, t.shed, t.deadline_shed, t.completed))
        .collect()
}

#[test]
fn differential_two_tenant_routing() {
    // 2 tenants x 2 groups at 300 µs/item: ~6.6k req/s capacity per
    // tenant vs 800 offered, queue >= trace — admission outcomes are
    // structurally determined, so server and DES must agree exactly
    let n = 200;
    let (trace, tags) = merged_two_tenant(n, 800.0, 21);
    let total = trace.len();
    let per_item = Duration::from_micros(300);
    let budgets = vec![Some(Duration::from_secs(1)); 2];
    let plan = two_tenant_plan(2, total);
    let est = vec![per_item; 4];

    let mut srv = Server::deploy(
        move |_| MockBackend::with_service(Duration::ZERO, per_item),
        plan.clone(),
    );
    srv.set_tenancy(budgets.clone(), est.clone());
    let fm = srv.replay_tagged(&trace, &tags, 8, 77);
    srv.shutdown();
    let srv_sum = fm.summary();

    let cfg = SimConfig { input_len: 8, seed: 77, ..SimConfig::default() };
    let backend = SimBackend::Mock { base: Duration::ZERO, per_item };
    let mut sim = FleetSim::uniform(plan, backend, cfg);
    sim.set_tenancy(budgets, est);
    let rep = sim.run_tagged(&trace, &tags);

    assert_eq!(srv_sum.submitted, total, "server accepted");
    assert_eq!(rep.submitted, total, "sim accepted");
    assert_eq!(rep.completed, total, "sim completed");
    assert_eq!((srv_sum.shed, srv_sum.deadline_shed), (0, 0), "server shed");
    assert_eq!((rep.shed, rep.deadline_shed), (0, 0), "sim shed");

    // per-tenant splits agree exactly, and each tenant saw its own n
    let (sc, mc) = (per_tenant_counts(&srv_sum), per_tenant_counts(&rep.summary));
    assert_eq!(sc, mc, "per-tenant counts diverged");
    for (t, &(sub, shed, dshed, done)) in sc.iter().enumerate() {
        assert_eq!((sub, shed, dshed, done), (n, 0, 0, n), "tenant {t}");
    }

    // round-robin inside each tenant's member list is a pure function of
    // the tagged submit order: per-group dispatch counts match exactly
    let per = |s: &FleetSummary| -> Vec<usize> {
        s.per_group.iter().map(|g| g.as_ref().map_or(0, |x| x.requests)).collect()
    };
    assert_eq!(per(&srv_sum), per(&rep.summary), "per-group dispatch counts");
}

#[test]
fn both_tenants_meet_slo_under_merged_diurnal() {
    // each tenant rides its own diurnal trace on its own group: 5k req/s
    // capacity vs <= 600 offered, so both must hold p99 inside budget
    let t0 = diurnal(400, 300.0, 600.0, 2.0, 31);
    let t1 = diurnal(400, 200.0, 500.0, 2.0, 32);
    let (trace, tags) = Trace::merge(&[(0, &t0), (1, &t1)]);
    let per_item = Duration::from_micros(200);
    let slos_ms = [250.0, 100.0];
    let budgets: Vec<Option<Duration>> =
        slos_ms.iter().map(|&ms| Some(Duration::from_secs_f64(ms * 1e-3))).collect();
    let plan = two_tenant_plan(1, 64);

    let cfg = SimConfig { input_len: 8, seed: 5, ..SimConfig::default() };
    let backend = SimBackend::Mock { base: Duration::ZERO, per_item };
    let mut sim = FleetSim::uniform(plan, backend, cfg);
    sim.set_tenancy(budgets, vec![per_item; 2]);
    let rep = sim.run_tagged(&trace, &tags);

    assert_eq!(rep.summary.per_tenant.len(), 2);
    for (t, ts) in rep.summary.per_tenant.iter().enumerate() {
        assert_eq!(ts.submitted, 400, "tenant {t} accepted everything");
        assert_eq!((ts.shed, ts.deadline_shed), (0, 0), "tenant {t} shed nothing");
        assert_eq!(ts.goodput, ts.completed, "tenant {t} completions all in budget");
        assert_eq!(ts.slo_ms, Some(slos_ms[t]), "tenant {t} SLO plumbed");
        let lat = ts.latency.as_ref().expect("tenant latency summary");
        assert!(
            lat.latency_ms.p99 <= slos_ms[t],
            "tenant {t} p99 {:.2} ms over its {:.0} ms SLO",
            lat.latency_ms.p99,
            slos_ms[t]
        );
    }
}

/// One flash-crowd zoo arm on the DES: tenant 0 bursts to 8x its base
/// rate against a group that serves ~500 req/s; `est_zero` selects the
/// FIFO baseline (only already-expired requests shed).
fn flash_arm(est_zero: bool) -> SimReport {
    let t0 = flash_crowd(600, 300.0, 8.0, 0.2, 0.5, 41);
    let t1 = poisson(300, 300.0, 42);
    let (trace, tags) = Trace::merge(&[(0, &t0), (1, &t1)]);
    let per_item = Duration::from_millis(2);
    let budgets = vec![Some(Duration::from_millis(40)), Some(Duration::from_millis(100))];
    let groups = vec![ChainGroup::new(1).for_tenant(0), ChainGroup::new(1).for_tenant(1)];
    let plan = Deployment { groups, ..Deployment::default() }
        .with_policy(Policy::RoundRobin)
        .with_batcher(BatcherConfig { max_batch: 1, max_wait: Duration::ZERO })
        .with_queue_depth(32)
        .with_window(2);
    let est = if est_zero { vec![Duration::ZERO; 2] } else { vec![per_item; 2] };

    let cfg = SimConfig { input_len: 8, seed: 9, ..SimConfig::default() };
    let backend = SimBackend::Mock { base: Duration::ZERO, per_item };
    let mut sim = FleetSim::uniform(plan, backend, cfg);
    sim.set_tenancy(budgets, est);
    sim.run_tagged(&trace, &tags)
}

#[test]
fn deadline_admission_beats_fifo_under_flash_crowd() {
    let fifo = flash_arm(true);
    let dl = flash_arm(false);

    // FIFO keeps everything a queue slot can hold — no deadline sheds —
    // and lets queued work rot past its budget; the deadline arm sheds
    // the infeasible tail up front and keeps accepted work inside it
    let (f0, d0) = (&fifo.summary.per_tenant[0], &dl.summary.per_tenant[0]);
    assert_eq!(fifo.deadline_shed, 0, "FIFO arm must not deadline-shed");
    assert!(d0.deadline_shed > 0, "deadline arm must shed infeasible work");
    assert!(
        d0.goodput > f0.goodput,
        "deadline arm goodput {} must beat FIFO {} for the bursting tenant",
        d0.goodput,
        f0.goodput
    );
    // deadline sheds are distinguishable from queue-full sheds
    assert_eq!(
        dl.summary.deadline_shed,
        dl.summary.per_tenant.iter().map(|t| t.deadline_shed).sum::<usize>(),
        "fleet deadline-shed must equal the per-tenant sum"
    );

    // the healthy tenant's trajectory is bit-identical across arms: its
    // group, budget headroom and arrivals never interact with tenant 0
    let (f1, d1) = (&fifo.summary.per_tenant[1], &dl.summary.per_tenant[1]);
    assert_eq!(
        (f1.submitted, f1.shed, f1.deadline_shed, f1.completed, f1.goodput),
        (d1.submitted, d1.shed, d1.deadline_shed, d1.completed, d1.goodput),
        "tenant 1 must be isolated from tenant 0's flash crowd"
    );
    assert_eq!(f1.shed + f1.deadline_shed, 0, "tenant 1 never sheds");
}

#[test]
fn server_deadline_sheds_attribute_to_the_bursting_tenant() {
    // the threaded counterpart of the flash-crowd arms: real clocks are
    // too noisy for exact goodput equality, but the admission *mechanism*
    // must behave identically — the deadline arm sheds infeasible work
    // for the bursting tenant only, the FIFO arm never deadline-sheds
    let t0 = flash_crowd(600, 300.0, 8.0, 0.2, 0.5, 41);
    let t1 = poisson(300, 300.0, 42);
    let (trace, tags) = Trace::merge(&[(0, &t0), (1, &t1)]);
    let per_item = Duration::from_millis(2);
    let budgets = vec![Some(Duration::from_millis(40)), Some(Duration::from_millis(100))];
    let groups = vec![ChainGroup::new(1).for_tenant(0), ChainGroup::new(1).for_tenant(1)];
    let plan = Deployment { groups, ..Deployment::default() }
        .with_policy(Policy::RoundRobin)
        .with_batcher(BatcherConfig { max_batch: 1, max_wait: Duration::ZERO })
        .with_queue_depth(32)
        .with_window(2);

    let run = |est: Vec<Duration>| -> FleetSummary {
        let mut srv = Server::deploy(
            move |_| MockBackend::with_service(Duration::ZERO, per_item),
            plan.clone(),
        );
        srv.set_tenancy(budgets.clone(), est);
        let fm = srv.replay_tagged(&trace, &tags, 8, 77);
        srv.shutdown();
        fm.summary()
    };

    let fifo = run(vec![Duration::ZERO; 2]);
    let dl = run(vec![per_item; 2]);

    assert_eq!(fifo.deadline_shed, 0, "server FIFO arm must not deadline-shed");
    assert!(
        dl.per_tenant[0].deadline_shed > 0,
        "server deadline arm must shed the bursting tenant's infeasible work"
    );
    assert_eq!(dl.per_tenant[1].deadline_shed, 0, "the healthy tenant must never deadline-shed");
    assert_eq!(
        dl.deadline_shed,
        dl.per_tenant.iter().map(|t| t.deadline_shed).sum::<usize>(),
        "fleet deadline-shed must equal the per-tenant sum"
    );
}
