//! Runtime golden tests: the rust PJRT engine must reproduce the python/jax
//! outputs bit-for-bit (integer-valued f32 math), across every artifact and
//! batch variant, plus error-path coverage. Requires `make artifacts`.

use fcmp::runtime::{read_f32_bin, Engine, Manifest};
use std::path::{Path, PathBuf};

fn artifacts() -> Option<PathBuf> {
    let p = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if p.join("mvau_unit.manifest").exists() {
        Some(p)
    } else {
        eprintln!("skipping: run `make artifacts` first");
        None
    }
}

#[test]
fn mvau_unit_kernel_matches_python() {
    let Some(arts) = artifacts() else { return };
    fcmp::runtime::check_mvau_unit(&arts).unwrap();
}

#[test]
fn cnv_w1a1_golden_exact() {
    let Some(arts) = artifacts() else { return };
    let eng = Engine::load(&arts, "cnv_w1a1").unwrap();
    eng.check_golden().unwrap();
}

#[test]
fn cnv_w2a2_golden_exact() {
    let Some(arts) = artifacts() else { return };
    Engine::load(&arts, "cnv_w2a2").unwrap().check_golden().unwrap();
}

#[test]
fn rn50_lite_golden_exact() {
    let Some(arts) = artifacts() else { return };
    Engine::load(&arts, "rn50_lite_w1a2").unwrap().check_golden().unwrap();
}

#[test]
fn batch_variants_agree_with_each_other() {
    // the b1 and b4 executables must give identical per-sample outputs
    let Some(arts) = artifacts() else { return };
    let eng = Engine::load(&arts, "cnv_w1a1").unwrap();
    assert_eq!(eng.batch_sizes(), vec![1, 4]);
    let per = eng.manifest.input_elements_per_sample() as usize;
    let mk = |seed: u64| -> Vec<f32> {
        let mut rng = fcmp::util::rng::Rng::new(seed);
        (0..per).map(|_| rng.below(256) as f32).collect()
    };
    let inputs: Vec<Vec<f32>> = (0..4).map(|i| mk(100 + i)).collect();
    // batch-of-4 path
    let batched = eng.infer(&inputs).unwrap();
    assert_eq!(batched.len(), 4);
    // one-at-a-time path
    for (i, x) in inputs.iter().enumerate() {
        let single = eng.infer(std::slice::from_ref(x)).unwrap();
        assert_eq!(single[0], batched[i], "sample {i} differs across variants");
    }
}

#[test]
fn outputs_are_integer_valued() {
    // the whole network is integer math in f32: outputs must be integers
    let Some(arts) = artifacts() else { return };
    let eng = Engine::load(&arts, "cnv_w1a1").unwrap();
    let per = eng.manifest.input_elements_per_sample() as usize;
    let x: Vec<f32> = (0..per).map(|i| (i % 256) as f32).collect();
    let y = eng.infer(&[x]).unwrap();
    for v in &y[0] {
        assert_eq!(*v, v.round(), "non-integer output {v}");
        assert!(v.abs() < 1e6, "implausible magnitude {v}");
    }
}

#[test]
fn deterministic_across_runs() {
    let Some(arts) = artifacts() else { return };
    let eng = Engine::load(&arts, "cnv_w2a2").unwrap();
    let per = eng.manifest.input_elements_per_sample() as usize;
    let x: Vec<f32> = (0..per).map(|i| ((i * 7) % 256) as f32).collect();
    let a = eng.infer(&[x.clone()]).unwrap();
    let b = eng.infer(&[x]).unwrap();
    assert_eq!(a, b);
}

#[test]
fn wrong_input_size_is_error_not_crash() {
    let Some(arts) = artifacts() else { return };
    let eng = Engine::load(&arts, "cnv_w1a1").unwrap();
    assert!(eng.infer(&[vec![1.0; 10]]).is_err());
    assert!(eng.infer(&[]).unwrap().is_empty());
}

#[test]
fn missing_model_is_error() {
    let Some(arts) = artifacts() else { return };
    assert!(Engine::load(&arts, "no_such_model").is_err());
}

#[test]
fn weight_files_match_manifest_shapes() {
    let Some(arts) = artifacts() else { return };
    for name in ["cnv_w1a1", "cnv_w2a2", "rn50_lite_w1a2"] {
        let m = Manifest::load(&arts.join(format!("{name}.manifest"))).unwrap();
        for spec in &m.params {
            let data = read_f32_bin(&arts.join(&spec.file)).unwrap();
            assert_eq!(data.len() as u64, spec.elements(), "{name}/{}", spec.file);
            // quantized values only (plus integer thresholds)
            for v in data.iter().take(256) {
                assert_eq!(*v, v.round(), "{name}/{}: non-integer {v}", spec.file);
            }
        }
    }
}
