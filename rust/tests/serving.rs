//! Serving-path integration tests on the deterministic mock backend over
//! the unified `Deployment` API: exact dispatch counts per scheduling
//! policy, admission-control shedding and the typed QueueFull/Closed
//! error split, shutdown-drain semantics, and a property test that fleet
//! completions are a permutation of submissions under every policy.

use std::time::Duration;

use fcmp::coordinator::{
    BatcherConfig, Completion, Deployment, MockBackend, Policy, Server, SubmitError,
};
use fcmp::util::prop;

fn plan(groups: usize, policy: Policy, queue_depth: usize, max_batch: usize) -> Deployment {
    Deployment::replicated(groups)
        .with_policy(policy)
        .with_batcher(BatcherConfig { max_batch, max_wait: Duration::from_millis(1) })
        .with_queue_depth(queue_depth)
}

/// Drain every remaining completion (call after `shutdown`).
fn drain(srv: &mut Server) -> Vec<Completion> {
    let mut out = Vec::new();
    while let Some(c) = srv.next_completion() {
        out.push(c);
    }
    out
}

#[test]
fn round_robin_splits_exactly_evenly() {
    let mut srv = Server::deploy(|_| MockBackend::instant(), plan(2, Policy::RoundRobin, 64, 1));
    for i in 0..40 {
        srv.submit_blocking(i, vec![i as f32]).unwrap();
    }
    srv.shutdown();
    let cs = drain(&mut srv);
    assert_eq!(cs.len(), 40);
    let c0 = cs.iter().filter(|c| c.group == 0).count();
    assert_eq!(c0, 20, "round-robin must alternate exactly");
}

#[test]
fn weighted_matches_capacity_ratio_exactly() {
    // SWRR with weights 3:1 dispatches exactly 30/10 over 40 requests
    let mut srv = Server::deploy(
        |_| MockBackend::instant(),
        plan(2, Policy::Weighted(vec![3.0, 1.0]), 64, 1),
    );
    for i in 0..40 {
        srv.submit_blocking(i, vec![1.0]).unwrap();
    }
    srv.shutdown();
    let cs = drain(&mut srv);
    assert_eq!(cs.len(), 40);
    let c0 = cs.iter().filter(|c| c.group == 0).count();
    assert_eq!(c0, 30, "weighted 3:1 must dispatch 30/10");
}

#[test]
fn jsq_steers_load_away_from_the_slow_group() {
    // group 0 takes 50 ms per batch, group 1 is instant; paced arrivals
    // let JSQ observe the asymmetry through the outstanding counters
    let mut srv = Server::deploy(
        |id| {
            if id.group == 0 {
                MockBackend::with_service(Duration::from_millis(50), Duration::ZERO)
            } else {
                MockBackend::instant()
            }
        },
        plan(2, Policy::JoinShortestQueue, 64, 1),
    );
    for i in 0..30 {
        srv.submit_blocking(i, vec![1.0]).unwrap();
        std::thread::sleep(Duration::from_millis(2));
    }
    srv.shutdown();
    let cs = drain(&mut srv);
    assert_eq!(cs.len(), 30);
    let c0 = cs.iter().filter(|c| c.group == 0).count();
    let c1 = cs.len() - c0;
    assert!(c1 >= 2 * c0, "JSQ sent {c0} to the slow group, {c1} to the fast one");
}

#[test]
fn overload_sheds_with_queue_full_and_recovers() {
    let mut srv = Server::deploy(
        |_| MockBackend::with_service(Duration::from_millis(30), Duration::ZERO),
        plan(1, Policy::RoundRobin, 2, 1),
    );
    // burst far beyond queue capacity: the excess must shed as QueueFull
    let mut shed = 0;
    for i in 0..40 {
        match srv.submit(i, vec![1.0]) {
            Ok(_) => {}
            Err(e @ SubmitError::QueueFull(_)) => {
                assert!(!e.is_closed());
                shed += 1;
            }
            Err(SubmitError::Closed(_)) => panic!("open server must never report Closed"),
        }
    }
    assert!(shed > 0, "burst must overflow the depth-2 queue");
    // after the backlog drains, admission recovers
    std::thread::sleep(Duration::from_millis(200));
    assert!(srv.submit(99, vec![1.0]).is_ok(), "queue must reopen after draining");
    srv.shutdown();
    let n = drain(&mut srv).len();
    assert_eq!(n, 40 - shed + 1, "every accepted request must complete");
}

#[test]
fn closed_error_is_distinct_from_queue_full() {
    let mut srv = Server::deploy(|_| MockBackend::instant(), plan(2, Policy::RoundRobin, 4, 1));
    srv.submit(0, vec![1.0]).unwrap();
    srv.shutdown();
    match srv.submit(1, vec![2.0]) {
        Err(SubmitError::Closed(r)) => {
            assert_eq!(r.id, 1);
            assert_eq!(r.input, vec![2.0], "the request must ride back intact");
        }
        other => panic!("want Closed after shutdown, got {other:?}"),
    }
    // the error is a real std error with distinct messages per variant,
    // and `?` converts it straight into anyhow::Result
    let closed = srv.submit(2, vec![1.0]).unwrap_err();
    assert!(closed.is_closed());
    assert!(format!("{closed}").contains("shut down"));
    let as_anyhow: anyhow::Error = closed.into();
    assert!(format!("{as_anyhow}").contains("request 2"));
}

#[test]
fn shutdown_drains_every_in_flight_request() {
    let mut srv = Server::deploy(
        |_| MockBackend::with_service(Duration::from_millis(1), Duration::from_millis(1)),
        plan(3, Policy::RoundRobin, 128, 4),
    );
    for i in 0..90 {
        srv.submit_blocking(i, vec![i as f32, 2.0]).unwrap();
    }
    // shutdown must wait for all three groups to drain their queues
    srv.shutdown();
    let cs = drain(&mut srv);
    assert_eq!(cs.len(), 90, "shutdown dropped in-flight requests");
    let mut ids: Vec<u64> = cs.iter().map(|c| c.id).collect();
    ids.sort_unstable();
    assert_eq!(ids, (0..90).collect::<Vec<u64>>());
    for c in &cs {
        // mock output[0] = sum of the request's inputs = id + 2
        assert_eq!(c.output[0], c.id as f32 + 2.0, "wrong output for {}", c.id);
        assert!(c.group < 3);
        assert_eq!(c.stage, 0, "flat groups complete at their only stage");
    }
}

#[test]
fn prop_fleet_completions_are_a_permutation_of_submissions() {
    // random (n, groups, policy) cases; under every policy, every
    // submitted id comes back exactly once with the right output
    prop::check(
        2024,
        12,
        |r| vec![1 + r.below(50), 1 + r.below(4), r.below(3)],
        |v: &Vec<u64>| {
            let n = v.first().copied().unwrap_or(8).clamp(1, 50);
            let groups = v.get(1).copied().unwrap_or(1).clamp(1, 4) as usize;
            let policy = match v.get(2).copied().unwrap_or(0) % 3 {
                0 => Policy::RoundRobin,
                1 => Policy::JoinShortestQueue,
                _ => Policy::Weighted((1..=groups).map(|i| i as f64).collect()),
            };
            let mut srv = Server::deploy(
                |_| MockBackend::instant(),
                Deployment::replicated(groups)
                    .with_policy(policy)
                    .with_batcher(BatcherConfig {
                        max_batch: 4,
                        max_wait: Duration::from_micros(200),
                    })
                    .with_queue_depth(64),
            );
            for i in 0..n {
                if srv.submit_blocking(i, vec![i as f32]).is_err() {
                    return Err("server closed during submit".to_string());
                }
            }
            srv.shutdown();
            let mut ids = Vec::new();
            while let Some(c) = srv.next_completion() {
                if c.output[0] != c.id as f32 {
                    return Err(format!("output mismatch for id {}", c.id));
                }
                if c.group >= groups {
                    return Err(format!("completion from unknown group {}", c.group));
                }
                ids.push(c.id);
            }
            ids.sort_unstable();
            let want: Vec<u64> = (0..n).collect();
            if ids == want {
                Ok(())
            } else {
                Err(format!("ids {ids:?} are not a permutation of 0..{n}"))
            }
        },
    );
}
