//! Serving-path integration tests on the deterministic mock backend over
//! the unified `Deployment` API: exact dispatch counts per scheduling
//! policy, admission-control shedding and the typed QueueFull/Closed/
//! Timeout error split, shutdown-drain semantics (including the async
//! in-flight window's drain barrier and mid-drain worker death), the
//! allocation-free steady state, and property tests that fleet
//! completions are a permutation of submissions under every policy and
//! window.

use std::time::{Duration, Instant};

use fcmp::coordinator::{
    uniform, BatcherConfig, Completion, Deployment, InferBackend, MockBackend,
    PipelinedMockBackend, Policy, Server, SubmitError,
};
use fcmp::util::prop;

fn plan(groups: usize, policy: Policy, queue_depth: usize, max_batch: usize) -> Deployment {
    Deployment::replicated(groups)
        .with_policy(policy)
        .with_batcher(BatcherConfig { max_batch, max_wait: Duration::from_millis(1) })
        .with_queue_depth(queue_depth)
}

/// Drain every remaining completion (call after `shutdown`).
fn drain(srv: &mut Server) -> Vec<Completion> {
    let mut out = Vec::new();
    while let Some(c) = srv.next_completion() {
        out.push(c);
    }
    out
}

#[test]
fn round_robin_splits_exactly_evenly() {
    let mut srv = Server::deploy(|_| MockBackend::instant(), plan(2, Policy::RoundRobin, 64, 1));
    for i in 0..40 {
        srv.submit_blocking(i, vec![i as f32]).unwrap();
    }
    srv.shutdown();
    let cs = drain(&mut srv);
    assert_eq!(cs.len(), 40);
    let c0 = cs.iter().filter(|c| c.group == 0).count();
    assert_eq!(c0, 20, "round-robin must alternate exactly");
}

#[test]
fn weighted_matches_capacity_ratio_exactly() {
    // SWRR with weights 3:1 dispatches exactly 30/10 over 40 requests
    let mut srv = Server::deploy(
        |_| MockBackend::instant(),
        plan(2, Policy::Weighted(vec![3.0, 1.0]), 64, 1),
    );
    for i in 0..40 {
        srv.submit_blocking(i, vec![1.0]).unwrap();
    }
    srv.shutdown();
    let cs = drain(&mut srv);
    assert_eq!(cs.len(), 40);
    let c0 = cs.iter().filter(|c| c.group == 0).count();
    assert_eq!(c0, 30, "weighted 3:1 must dispatch 30/10");
}

#[test]
fn jsq_steers_load_away_from_the_slow_group() {
    // group 0 takes 50 ms per batch, group 1 is instant; paced arrivals
    // let JSQ observe the asymmetry through the outstanding counters
    let mut srv = Server::deploy(
        |id| {
            if id.group == 0 {
                MockBackend::with_service(Duration::from_millis(50), Duration::ZERO)
            } else {
                MockBackend::instant()
            }
        },
        plan(2, Policy::JoinShortestQueue, 64, 1),
    );
    for i in 0..30 {
        srv.submit_blocking(i, vec![1.0]).unwrap();
        std::thread::sleep(Duration::from_millis(2));
    }
    srv.shutdown();
    let cs = drain(&mut srv);
    assert_eq!(cs.len(), 30);
    let c0 = cs.iter().filter(|c| c.group == 0).count();
    let c1 = cs.len() - c0;
    assert!(c1 >= 2 * c0, "JSQ sent {c0} to the slow group, {c1} to the fast one");
}

#[test]
fn overload_sheds_with_queue_full_and_recovers() {
    let mut srv = Server::deploy(
        |_| MockBackend::with_service(Duration::from_millis(30), Duration::ZERO),
        plan(1, Policy::RoundRobin, 2, 1),
    );
    // burst far beyond queue capacity: the excess must shed as QueueFull
    let mut shed = 0;
    for i in 0..40 {
        match srv.submit(i, vec![1.0]) {
            Ok(_) => {}
            Err(e @ SubmitError::QueueFull(_)) => {
                assert!(!e.is_closed());
                shed += 1;
            }
            Err(SubmitError::Timeout(_)) => panic!("plain submit never waits, never times out"),
            Err(SubmitError::DeadlineInfeasible(_)) => {
                panic!("no deadline was stamped, nothing can be infeasible")
            }
            Err(SubmitError::Closed(_)) => panic!("open server must never report Closed"),
        }
    }
    assert!(shed > 0, "burst must overflow the depth-2 queue");
    // after the backlog drains, admission recovers
    std::thread::sleep(Duration::from_millis(200));
    assert!(srv.submit(99, vec![1.0]).is_ok(), "queue must reopen after draining");
    srv.shutdown();
    let n = drain(&mut srv).len();
    assert_eq!(n, 40 - shed + 1, "every accepted request must complete");
}

#[test]
fn closed_error_is_distinct_from_queue_full() {
    let mut srv = Server::deploy(|_| MockBackend::instant(), plan(2, Policy::RoundRobin, 4, 1));
    srv.submit(0, vec![1.0]).unwrap();
    srv.shutdown();
    match srv.submit(1, vec![2.0]) {
        Err(SubmitError::Closed(r)) => {
            assert_eq!(r.id, 1);
            assert_eq!(r.input, vec![2.0], "the request must ride back intact");
        }
        other => panic!("want Closed after shutdown, got {other:?}"),
    }
    // the error is a real std error with distinct messages per variant,
    // and `?` converts it straight into anyhow::Result
    let closed = srv.submit(2, vec![1.0]).unwrap_err();
    assert!(closed.is_closed());
    assert!(format!("{closed}").contains("shut down"));
    let as_anyhow: anyhow::Error = closed.into();
    assert!(format!("{as_anyhow}").contains("request 2"));
}

#[test]
fn shutdown_drains_every_in_flight_request() {
    let mut srv = Server::deploy(
        |_| MockBackend::with_service(Duration::from_millis(1), Duration::from_millis(1)),
        plan(3, Policy::RoundRobin, 128, 4),
    );
    for i in 0..90 {
        srv.submit_blocking(i, vec![i as f32, 2.0]).unwrap();
    }
    // shutdown must wait for all three groups to drain their queues
    srv.shutdown();
    let cs = drain(&mut srv);
    assert_eq!(cs.len(), 90, "shutdown dropped in-flight requests");
    let mut ids: Vec<u64> = cs.iter().map(|c| c.id).collect();
    ids.sort_unstable();
    assert_eq!(ids, (0..90).collect::<Vec<u64>>());
    for c in &cs {
        // mock output[0] = sum of the request's inputs = id + 2
        assert_eq!(c.output[0], c.id as f32 + 2.0, "wrong output for {}", c.id);
        assert!(c.group < 3);
        assert_eq!(c.stage, 0, "flat groups complete at their only stage");
    }
}

#[test]
fn prop_windowed_drain_completes_every_accepted_submission() {
    // the drain-barrier property across in-flight windows: for every
    // window in {1, 2, 4}, with overlapping backends of *different*
    // speeds per group (jittering completion order across the fleet),
    // every accepted submission comes back exactly once with the right
    // output
    prop::check(
        7031,
        9,
        |r| vec![8 + r.below(40), r.below(3), r.below(3)],
        |v: &Vec<u64>| {
            let n = v.first().copied().unwrap_or(16).clamp(1, 48);
            let window = 1usize << (v.get(1).copied().unwrap_or(1) % 3); // 1, 2, 4
            let policy = match v.get(2).copied().unwrap_or(0) % 3 {
                0 => Policy::RoundRobin,
                1 => Policy::JoinShortestQueue,
                _ => Policy::Weighted(vec![2.0, 1.0]),
            };
            let mut srv = Server::deploy(
                |id| {
                    // group 0 transfer-bound, group 1 compute-bound, and
                    // unequal totals: completions interleave unevenly
                    if id.group == 0 {
                        PipelinedMockBackend::overlapped(
                            Duration::from_micros(400),
                            Duration::from_micros(100),
                        )
                    } else {
                        PipelinedMockBackend::overlapped(
                            Duration::from_micros(100),
                            Duration::from_micros(700),
                        )
                    }
                },
                Deployment::replicated(2)
                    .with_policy(policy)
                    .with_batcher(BatcherConfig {
                        max_batch: 3,
                        max_wait: Duration::from_micros(200),
                    })
                    .with_queue_depth(16)
                    .with_window(window),
            );
            for i in 0..n {
                if srv.submit_blocking(i, vec![i as f32]).is_err() {
                    return Err("server closed during submit".to_string());
                }
            }
            srv.shutdown();
            let mut ids = Vec::new();
            while let Some(c) = srv.next_completion() {
                if c.output[0] != c.id as f32 {
                    return Err(format!("output mismatch for id {}", c.id));
                }
                ids.push(c.id);
            }
            ids.sort_unstable();
            let want: Vec<u64> = (0..n).collect();
            if ids == want {
                Ok(())
            } else {
                Err(format!("window {window}: ids {ids:?} != 0..{n}"))
            }
        },
    );
}

/// Panics (poisoned-thread style) on any batch carrying the magic value,
/// exercising worker death with batches still in the in-flight window.
struct PoisonBackend;

impl InferBackend for PoisonBackend {
    fn infer_batch(&self, inputs: &[Vec<f32>]) -> fcmp::Result<Vec<Vec<f32>>> {
        if inputs.iter().any(|x| x.first() == Some(&-1.0)) {
            panic!("poisoned batch");
        }
        std::thread::sleep(Duration::from_micros(200) * inputs.len() as u32);
        Ok(inputs.iter().map(|x| vec![x.iter().sum()]).collect())
    }
}

#[test]
fn mid_drain_worker_panic_never_hangs_shutdown() {
    // a worker that dies with requests queued and in flight must not
    // deadlock the drain barrier: the other group keeps completing, the
    // dead group's accepted-but-unserved requests are lost (bounded by
    // its queue depth + in-flight window), and shutdown returns
    let queue_depth = 4;
    let mut srv = Server::deploy(
        |_| PoisonBackend,
        Deployment::replicated(2)
            .with_policy(Policy::RoundRobin)
            .with_batcher(BatcherConfig { max_batch: 1, max_wait: Duration::from_micros(100) })
            .with_queue_depth(queue_depth)
            .with_window(2),
    );
    let n: u64 = 30;
    let mut accepted = 0usize;
    for i in 0..n {
        // round-robin sends the poison into one group's worker
        let input = if i == 0 { vec![-1.0] } else { vec![i as f32] };
        if srv.submit(i, input).is_ok() {
            accepted += 1;
        }
        std::thread::sleep(Duration::from_micros(300));
    }
    assert!(srv.dead_groups() > 0, "poison must kill a worker");
    srv.shutdown();
    let cs = drain(&mut srv);
    assert!(
        !cs.iter().any(|c| c.id == 0),
        "the poisoned request must never complete"
    );
    for c in &cs {
        assert_eq!(c.output[0], c.id as f32, "wrong output for {}", c.id);
    }
    // everything except the poison and what died inside the dead worker's
    // queue + window survives
    let lost_bound = queue_depth + 2 + 1;
    assert!(
        cs.len() + lost_bound >= accepted,
        "{} completions for {accepted} accepted (bound {lost_bound})",
        cs.len()
    );
    assert!(cs.len() >= (n as usize) / 2, "the healthy group must keep serving");
}

#[test]
fn steady_state_submit_path_allocates_nothing() {
    // prime the pool above the fleet's concurrency, replay a trace, and
    // assert every request buffer was recycled: zero pool misses means
    // zero per-request heap allocations on the submit path
    let input_len = 8;
    let mut srv = Server::deploy(
        |_| MockBackend::instant(),
        Deployment::replicated(2)
            .with_policy(Policy::RoundRobin)
            .with_batcher(BatcherConfig { max_batch: 4, max_wait: Duration::from_micros(200) })
            .with_queue_depth(32),
    );
    srv.buffer_pool().prime(64, input_len);
    let fm = srv.replay(&uniform(300, 4000.0), input_len, 42);
    assert_eq!(fm.completed(), 300);
    let hot = fm.summary().hot;
    assert_eq!(hot.submits, 300);
    assert_eq!(hot.pool_misses, 0, "steady-state submit path allocated: {hot:?}");
    assert!(hot.pool_hits >= 300, "every request must draw from the pool: {hot:?}");
    assert!(hot.pool_returns > 0, "worker reaps must recycle buffers: {hot:?}");
    srv.shutdown();
}

#[test]
fn deeper_window_overlaps_transfer_with_compute() {
    // one replica, balanced 3ms transfer / 3ms compute legs: window 1
    // pays both legs per batch, window 4 hides the transfer behind the
    // previous batch's compute, so the same load finishes markedly faster
    let run = |window: usize| -> Duration {
        let mut srv = Server::deploy(
            |_| {
                PipelinedMockBackend::overlapped(
                    Duration::from_millis(3),
                    Duration::from_millis(3),
                )
            },
            Deployment::replicated(1)
                .with_batcher(BatcherConfig { max_batch: 4, max_wait: Duration::from_micros(100) })
                .with_queue_depth(64)
                .with_window(window),
        );
        let t0 = Instant::now();
        for i in 0..64 {
            srv.submit_blocking(i, vec![1.0]).unwrap();
        }
        srv.shutdown();
        let n = drain(&mut srv).len();
        let wall = t0.elapsed();
        assert_eq!(n, 64);
        wall
    };
    let w1 = run(1);
    let w4 = run(4);
    assert!(
        w1.as_secs_f64() >= 1.25 * w4.as_secs_f64(),
        "window 4 ({w4:?}) must beat window 1 ({w1:?}) by ≥1.25x on balanced legs"
    );
}

#[test]
fn prop_fleet_completions_are_a_permutation_of_submissions() {
    // random (n, groups, policy) cases; under every policy, every
    // submitted id comes back exactly once with the right output
    prop::check(
        2024,
        12,
        |r| vec![1 + r.below(50), 1 + r.below(4), r.below(3)],
        |v: &Vec<u64>| {
            let n = v.first().copied().unwrap_or(8).clamp(1, 50);
            let groups = v.get(1).copied().unwrap_or(1).clamp(1, 4) as usize;
            let policy = match v.get(2).copied().unwrap_or(0) % 3 {
                0 => Policy::RoundRobin,
                1 => Policy::JoinShortestQueue,
                _ => Policy::Weighted((1..=groups).map(|i| i as f64).collect()),
            };
            let mut srv = Server::deploy(
                |_| MockBackend::instant(),
                Deployment::replicated(groups)
                    .with_policy(policy)
                    .with_batcher(BatcherConfig {
                        max_batch: 4,
                        max_wait: Duration::from_micros(200),
                    })
                    .with_queue_depth(64),
            );
            for i in 0..n {
                if srv.submit_blocking(i, vec![i as f32]).is_err() {
                    return Err("server closed during submit".to_string());
                }
            }
            srv.shutdown();
            let mut ids = Vec::new();
            while let Some(c) = srv.next_completion() {
                if c.output[0] != c.id as f32 {
                    return Err(format!("output mismatch for id {}", c.id));
                }
                if c.group >= groups {
                    return Err(format!("completion from unknown group {}", c.group));
                }
                ids.push(c.id);
            }
            ids.sort_unstable();
            let want: Vec<u64> = (0..n).collect();
            if ids == want {
                Ok(())
            } else {
                Err(format!("ids {ids:?} are not a permutation of 0..{n}"))
            }
        },
    );
}
