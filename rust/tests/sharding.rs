//! Pipeline-parallel sharding: end-to-end acceptance (the 2×7012S CNV
//! port the single device cannot host), partition invariant property
//! tests (contiguous, exhaustive, non-overlapping, bottleneck-optimal),
//! staged-pipeline sim vs analytic model, and chain-group serving through
//! the unified `Deployment` coordinator — single chains, flat-fleet
//! equivalence with the pre-`Deployment` router, and the replicated-chain
//! topology whose throughput beats one chain's.

use std::time::Duration;

use fcmp::coordinator::{
    shard_service_times, BatcherConfig, Deployment, FleetMetrics, MockBackend, Policy,
    Server, SubmitError, WorkerId,
};
use fcmp::device::{self, Device};
use fcmp::nn::{cnv, CnvVariant};
use fcmp::sharding::{
    cut_traffic_bits, fits_packed, partition, Evaluator, LinkSpec, PartitionConfig,
};
use fcmp::sim;
use fcmp::util::prop;
use fcmp::util::rng::Rng;

fn ffd_cfg() -> PartitionConfig {
    PartitionConfig { generations: 0, ..PartitionConfig::default() }
}

/// The acceptance scenario: CNV-W2A2 overflows one 7012S even packed, a
/// two-7012S pipeline hosts it, and the staged-pipeline sim's steady-state
/// FPS matches the analytic bottleneck-II model within 1%. Runs the real
/// GA engine (reduced generations), as `fcmp shard` does by default.
#[test]
fn cnv_on_two_7012s_sim_matches_analytic_within_one_percent() {
    let net = cnv(CnvVariant::W2A2);
    let small = device::zynq_7012s();
    let cfg = PartitionConfig { generations: 25, ..PartitionConfig::default() };

    assert!(
        !fits_packed(&net, &small, cfg),
        "CNV-W2A2 packed must overflow a single 7012S for this scenario"
    );
    let plan = partition(&net, &[small.clone(), small], cfg).expect("2-shard cover");
    assert_eq!(plan.shards.len(), 2);
    for s in &plan.shards {
        assert!(s.fits());
    }

    let r = sim::simulate_sharded(&net, &plan, 400, 8);
    assert!(
        (r.vs_analytic - 1.0).abs() <= 0.01,
        "sim {:.0} FPS vs analytic {:.0}: ratio {:.4} outside 1%",
        r.fps,
        plan.fps,
        r.vs_analytic
    );
}

/// Partition invariants under random (network, fleet) draws: the chosen
/// cover is contiguous, exhaustive and non-overlapping, and its bottleneck
/// is <= the bottleneck of every feasible sampled alternative cut vector.
#[test]
fn prop_partition_cover_invariants_and_bottleneck_optimality() {
    let pool: Vec<Device> = vec![
        device::zynq_7020(),
        device::zynq_7012s(),
        device::alveo_u250(),
        device::alveo_u280(),
    ];
    prop::check(
        4242,
        10,
        |r: &mut Rng| {
            // (variant, k, device picks..., alt seed)
            vec![r.below(2), 2 + r.below(2), r.below(4), r.below(4), r.below(4), r.next_u64()]
        },
        |v: &Vec<u64>| {
            // defensive indexing: the shrinker may hand back shorter vectors
            let at = |i: usize| v.get(i).copied().unwrap_or(0);
            let net = if at(0) == 0 {
                cnv(CnvVariant::W1A1)
            } else {
                cnv(CnvVariant::W2A2)
            };
            let k = at(1).clamp(2, 3) as usize;
            let devices: Vec<Device> =
                (0..k).map(|i| pool[at(2 + i) as usize % pool.len()].clone()).collect();
            let n = net.stages.len();
            let plan = match partition(&net, &devices, ffd_cfg()) {
                Err(_) => return Ok(()), // infeasible mixes are legitimate
                Ok(p) => p,
            };

            // cover: contiguous, exhaustive, non-overlapping
            let a = plan.assignment();
            if a.len() != n {
                return Err(format!("cover has {} entries for {n} stages", a.len()));
            }
            if a[0] != 0 || *a.last().unwrap() != k - 1 {
                return Err(format!("cover must span shard 0..{k}: {a:?}"));
            }
            if !a.windows(2).all(|w| w[1] == w[0] || w[1] == w[0] + 1) {
                return Err(format!("cover not contiguous/monotone: {a:?}"));
            }
            for (j, s) in plan.shards.iter().enumerate() {
                if s.stages.0 >= s.stages.1 {
                    return Err(format!("shard {j} empty: {:?}", s.stages));
                }
                if j > 0 && plan.shards[j - 1].stages.1 != s.stages.0 {
                    return Err(format!("shard {j} overlaps or gaps"));
                }
                if !s.fits() {
                    return Err(format!("shard {j} overflows its device"));
                }
            }

            // optimality: no sampled feasible alternative beats the DP
            let mut ev = Evaluator::new(&net, ffd_cfg());
            let mut rng = Rng::new(at(5));
            for _ in 0..12 {
                let mut cuts: Vec<usize> =
                    (0..k - 1).map(|_| 1 + rng.below(n as u64 - 1) as usize).collect();
                cuts.sort_unstable();
                cuts.dedup();
                if cuts.len() != k - 1 {
                    continue;
                }
                if let Some(alt) = ev.bottleneck_of(&devices, &cuts) {
                    if plan.bottleneck_s > alt + 1e-12 {
                        return Err(format!(
                            "cuts {cuts:?} reach {alt:.3e}s < chosen {:.3e}s",
                            plan.bottleneck_s
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

/// A frame must traverse every shard in order: with batch-1 instant mocks
/// each stage maps `[x, ..] -> [sum, 1]`, so after k stages the output is
/// `input + k - 1`; the completion carries k per-stage latencies and the
/// fleet metrics report a per-stage breakdown plus per-group and
/// fleet-wide end-to-end p99.
#[test]
fn chain_frames_traverse_all_shards_in_order_with_e2e_p99() {
    let net = cnv(CnvVariant::W2A2);
    let small = device::zynq_7012s();
    let plan = partition(&net, &[small.clone(), small], ffd_cfg()).expect("2-shard cover");
    let k = plan.shards.len();
    let svc = shard_service_times(&plan);
    // scale analytic service into the microsecond range so the test is fast
    // but ordering/latency accounting still exercises real sleeps
    let svc: Vec<Duration> = svc
        .iter()
        .map(|d| Duration::from_micros((d.as_micros() as u64).clamp(50, 500)))
        .collect();
    let dep = Deployment::chain(k)
        .with_batcher(BatcherConfig { max_batch: 1, max_wait: Duration::from_millis(1) })
        .with_queue_depth(32);
    let mut srv = Server::deploy(
        move |id: WorkerId| MockBackend::with_service(Duration::ZERO, svc[id.stage]),
        dep,
    );
    let n = 40u64;
    for i in 0..n {
        srv.submit_blocking(i, vec![i as f32]).unwrap();
    }
    srv.shutdown();

    let mut fm = FleetMetrics::new(&[k]);
    fm.start();
    let mut seen = 0;
    while let Some(c) = srv.next_completion() {
        seen += 1;
        assert_eq!(
            c.output[0],
            c.id as f32 + (k - 1) as f32,
            "frame {} did not traverse all {k} shards in order",
            c.id
        );
        assert_eq!(c.group, 0, "one chain, one group");
        assert_eq!(c.stage, k - 1, "completions must come from the last shard");
        assert_eq!(c.stage_latencies.len(), k);
        fm.record(&c);
    }
    assert_eq!(seen, n as usize, "chain dropped frames");

    let s = fm.summary();
    let fleet = s.fleet.expect("end-to-end summary");
    assert!(fleet.latency_ms.p99 > 0.0, "end-to-end p99 must be reported");
    let group = s.per_group[0].as_ref().expect("per-group e2e summary");
    assert_eq!(group.requests, n as usize);
    assert!((group.latency_ms.p99 - fleet.latency_ms.p99).abs() < 1e-9);
    assert_eq!(s.per_replica.len(), k);
    for (i, stage) in s.per_replica.iter().enumerate() {
        let stage = stage.as_ref().unwrap_or_else(|| panic!("stage {i} idle"));
        assert_eq!(stage.requests, n as usize);
        // per-stage transit is bounded by the end-to-end latency
        assert!(stage.latency_ms.median <= fleet.latency_ms.max + 1e-6);
    }
}

/// A full chain entry queue sheds (QueueFull, not Closed) and never
/// routes a frame into a mid-chain stage.
#[test]
fn chain_backpressure_sheds_at_stage_zero_only() {
    let dep = Deployment::chain(3)
        .with_batcher(BatcherConfig { max_batch: 1, max_wait: Duration::from_millis(0) })
        .with_queue_depth(1);
    let mut srv = Server::deploy(
        |id: WorkerId| {
            if id.stage == 0 {
                MockBackend::with_service(Duration::from_millis(40), Duration::ZERO)
            } else {
                MockBackend::instant()
            }
        },
        dep,
    );
    let mut shed = 0;
    for i in 0..30 {
        match srv.submit(i, vec![1.0]) {
            Ok(group) => assert_eq!(group, 0, "a single chain is group 0"),
            Err(e @ SubmitError::QueueFull(_)) => {
                assert!(!e.is_closed());
                shed += 1;
            }
            Err(SubmitError::Timeout(_)) => panic!("plain submit never waits, never times out"),
            Err(SubmitError::DeadlineInfeasible(_)) => {
                panic!("no deadline was stamped, nothing can be infeasible")
            }
            Err(SubmitError::Closed(_)) => panic!("open chain must shed, not close"),
        }
    }
    assert!(shed > 0, "depth-1 entry queue behind a slow stage must shed");
    srv.shutdown();
    let mut completed = 0;
    while let Some(c) = srv.next_completion() {
        assert_eq!(c.stage_latencies.len(), 3);
        completed += 1;
    }
    assert_eq!(completed, 30 - shed, "accepted frames must all drain");
}

/// Deployment equivalence (acceptance): a plan of N 1-stage groups
/// reproduces the PR-2 flat-fleet dispatch *exactly* — round-robin
/// alternates, SWRR honours the 3:1 ratio, and a chain-shaped metrics
/// collector is not involved anywhere.
#[test]
fn flat_deployment_reproduces_replicated_fleet_dispatch_exactly() {
    // round-robin over 2 one-stage groups: exact alternation, so the two
    // groups split 40 requests 20/20 like the old replicated router
    let rr = Deployment::replicated(2)
        .with_batcher(BatcherConfig { max_batch: 1, max_wait: Duration::from_millis(1) })
        .with_queue_depth(64);
    let mut srv = Server::deploy(|_| MockBackend::instant(), rr);
    for i in 0..40 {
        srv.submit_blocking(i, vec![1.0]).unwrap();
    }
    srv.shutdown();
    let mut counts = [0usize; 2];
    while let Some(c) = srv.next_completion() {
        assert!(c.stage_latencies.is_empty(), "flat groups must not report chain hops");
        assert_eq!(c.stage, 0);
        counts[c.group] += 1;
    }
    assert_eq!(counts, [20, 20], "round-robin dispatch drifted from the flat fleet");

    // weighted 3:1 over 2 groups of 1 stage: SWRR dispatches 30/10
    // exactly as the PR-2 router did over replicas
    let sw = Deployment::replicated(2)
        .with_policy(Policy::Weighted(vec![3.0, 1.0]))
        .with_batcher(BatcherConfig { max_batch: 1, max_wait: Duration::from_millis(1) })
        .with_queue_depth(64);
    let mut srv = Server::deploy(|_| MockBackend::instant(), sw);
    for i in 0..40 {
        srv.submit_blocking(i, vec![1.0]).unwrap();
    }
    srv.shutdown();
    let mut counts = [0usize; 2];
    while let Some(c) = srv.next_completion() {
        counts[c.group] += 1;
    }
    assert_eq!(counts, [30, 10], "SWRR dispatch drifted from the flat fleet");
}

/// Replicated chains (acceptance): two parallel copies of a 2-stage chain
/// complete strictly more of an offered load than one copy can, shed
/// strictly less, and report per-group end-to-end p99 — the topology the
/// old start/start_chain split could not express.
#[test]
fn replicated_chains_beat_one_chain_throughput() {
    // each stage serves 2 ms/frame: one 2-stage chain sustains ~500
    // frames/s; offer ~800/s so a single chain must shed while two chains
    // (~1000/s aggregate) absorb nearly everything
    let stage_service = Duration::from_millis(2);
    let requests = 240usize;
    let rate = 800.0;
    let run = |chains: usize| {
        let dep = Deployment::replicated_chains(chains, 2)
            .with_batcher(BatcherConfig { max_batch: 1, max_wait: Duration::from_millis(1) })
            .with_queue_depth(8);
        let mut srv = Server::deploy(
            move |_| MockBackend::with_service(Duration::ZERO, stage_service),
            dep,
        );
        let trace = fcmp::coordinator::uniform(requests, rate);
        let fm = srv.replay(&trace, 4, 99);
        srv.shutdown();
        fm
    };
    let one = run(1);
    let two = run(2);
    let one_summary = one.summary();
    let two_summary = two.summary();
    assert!(
        one.shed() > 0,
        "one chain absorbed the whole 1.6x-overload trace — the scenario lost its signal"
    );
    assert!(
        two.completed() > one.completed(),
        "2 chains completed {} <= 1 chain's {}",
        two.completed(),
        one.completed()
    );
    assert!(
        two.shed() < one.shed(),
        "2 chains shed {} >= 1 chain's {}",
        two.shed(),
        one.shed()
    );
    // the replicated-chain summary carries a per-group e2e p99 per copy
    assert_eq!(two_summary.per_group.len(), 2);
    for (g, s) in two_summary.per_group.iter().enumerate() {
        let s = s.as_ref().unwrap_or_else(|| panic!("group {g} idled"));
        assert!(s.latency_ms.p99 > 0.0);
        assert!(s.requests > 0);
    }
    assert_eq!(two_summary.per_replica.len(), 4, "2 groups x 2 stages");
    assert_eq!(one_summary.per_group.len(), 1);
}

/// Link modelling plumbs through the plan: a bandwidth-starved link caps
/// the pipeline and the sim agrees with the link-bound analytic model too.
#[test]
fn link_bound_plan_simulates_to_the_link_rate() {
    let net = cnv(CnvVariant::W2A2);
    let small = device::zynq_7012s();
    let cfg = PartitionConfig {
        generations: 0,
        link: LinkSpec { gbps: 0.001, latency_us: 5.0 },
        ..PartitionConfig::default()
    };
    let plan = partition(&net, &[small.clone(), small], cfg).expect("cover");
    assert!(plan.bottleneck_is_link());
    // the chosen cut still minimizes the bottleneck: it must carry less
    // traffic than the paper-obvious midpoint if that midpoint is worse
    let cut = plan.shards[0].stages.1;
    let bits = cut_traffic_bits(&net, cut - 1);
    assert_eq!(plan.links[0].bits_per_frame, bits);
    let r = sim::simulate_sharded(&net, &plan, 300, 8);
    assert!(
        (r.vs_analytic - 1.0).abs() <= 0.01,
        "link-bound sim ratio {:.4}",
        r.vs_analytic
    );
}

/// The report layer's sharding table renders well-formed rows for every
/// mix (including the infeasible single-device rows).
#[test]
fn shard_report_table_well_formed() {
    let t = fcmp::report::shard_table(8);
    let csv = t.to_csv();
    let cols = csv.lines().next().unwrap().split(',').count();
    assert!(csv.lines().count() >= 7, "{csv}");
    for line in csv.lines() {
        assert_eq!(line.split(',').count(), cols, "{line}");
    }
    // the headline story: one 7012S cannot host CNV-W2A2, two can
    let no = csv.lines().find(|l| l.contains("zynq-7012s,1")).unwrap();
    assert!(no.contains(",no,"), "{no}");
    let yes = csv.lines().find(|l| l.contains("zynq-7012s+zynq-7012s,2")).unwrap();
    assert!(yes.contains(",yes,"), "{yes}");
}
