//! The discrete-event fleet simulator vs. the thread-backed server.
//!
//! Three suites keep `FleetSim` honest:
//!
//! * **Differential**: small no-overload fleets where admission outcomes
//!   are structurally determined (queue depth ≥ trace length, offered
//!   rate under capacity) — the DES and `Server::deploy` + `replay` must
//!   agree on accepted/shed *exactly*, on throughput and fleet p99
//!   within a loose band (the threaded side sleeps on real clocks and a
//!   shared CI runner is noisy), and — for the load-blind policies — on
//!   the exact per-group dispatch counts.
//! * **Determinism**: same seed + trace ⇒ bit-identical event-order
//!   hash, `FleetSummary` and `ControlEvent` journal, run-to-run and
//!   across OS threads (the simulator must not read host time or
//!   iteration-order-unstable containers).
//! * **Fuzz**: randomized valid topologies under bursty traces preserve
//!   the conservation invariants (every offered request is accepted or
//!   shed exactly once, every accepted request completes, bounded
//!   queues never exceed their depth). Timestamp monotonicity and
//!   exactly-once completion are asserted inside the simulator itself,
//!   so any violation panics the run.

use std::time::Duration;

use fcmp::control::{AutoscalerConfig, SignalConfig, SloConfig};
use fcmp::coordinator::{
    bursty, diurnal, poisson, BatcherConfig, Deployment, FleetSummary, MockBackend, Policy,
    Server, Trace,
};
use fcmp::sim::{FleetSim, SimBackend, SimConfig, SimControl, SimReport};
use fcmp::util::prop;
use fcmp::util::rng::Rng;

fn mock_sim(per_item: Duration) -> SimBackend {
    SimBackend::Mock { base: Duration::ZERO, per_item }
}

/// Run the same plan + trace + seed through the thread-backed server and
/// the DES.
fn run_pair(plan: Deployment, per_item: Duration, trace: &Trace) -> (FleetSummary, SimReport) {
    let mut srv =
        Server::deploy(move |_| MockBackend::with_service(Duration::ZERO, per_item), plan.clone());
    let fm = srv.replay(trace, 8, 77);
    srv.shutdown();
    let cfg = SimConfig { input_len: 8, seed: 77, ..SimConfig::default() };
    let rep = FleetSim::uniform(plan, mock_sim(per_item), cfg).run(trace);
    (fm.summary(), rep)
}

/// The differential contract for a no-overload configuration.
///
/// `groups_exact` additionally requires identical per-group completion
/// counts — valid for load-blind policies (RR, equal-weight SWRR) where
/// the dispatch sequence is a pure function of the submit order; JSQ
/// reads live load, which legitimately differs between real and virtual
/// clocks.
fn assert_pair(name: &str, n: usize, groups_exact: bool, srv: &FleetSummary, sim: &SimReport) {
    assert_eq!(srv.submitted, n, "{name}: server accepted");
    assert_eq!(srv.shed, 0, "{name}: server shed");
    assert_eq!(sim.submitted, n, "{name}: sim accepted");
    assert_eq!(sim.shed, 0, "{name}: sim shed");
    assert_eq!(sim.completed, n, "{name}: sim completed");
    let sf = srv.fleet.as_ref().expect("server summary");
    let mf = sim.summary.fleet.as_ref().expect("sim summary");
    assert_eq!(sf.requests, mf.requests, "{name}: completion counts");

    let ratio = mf.throughput_fps / sf.throughput_fps.max(1e-9);
    assert!(
        (0.35..=3.0).contains(&ratio),
        "{name}: sim throughput {:.0} fps vs server {:.0} fps (ratio {ratio:.2})",
        mf.throughput_fps,
        sf.throughput_fps
    );
    let (sp99, mp99) = (sf.latency_ms.p99, mf.latency_ms.p99);
    assert!(
        sp99 <= mp99 * 5.0 + 25.0 && mp99 <= sp99 * 5.0 + 25.0,
        "{name}: fleet p99 diverged — server {sp99:.2} ms vs sim {mp99:.2} ms"
    );

    if groups_exact {
        let per = |s: &FleetSummary| -> Vec<usize> {
            s.per_group.iter().map(|g| g.as_ref().map_or(0, |x| x.requests)).collect()
        };
        assert_eq!(
            per(srv),
            per(&sim.summary),
            "{name}: per-group dispatch counts must match exactly"
        );
    } else {
        // JSQ spreads by live load: still every group must have served
        // something under a smooth trace over identical workers
        for (g, s) in sim.summary.per_group.iter().enumerate() {
            assert!(s.is_some(), "{name}: sim group {g} served nothing");
        }
    }
}

fn policies(groups: usize) -> [(Policy, bool, &'static str); 3] {
    [
        (Policy::RoundRobin, true, "rr"),
        (Policy::JoinShortestQueue, false, "jsq"),
        (Policy::Weighted(vec![1.0; groups]), true, "swrr"),
    ]
}

#[test]
fn differential_flat_fleet() {
    // 3 flat groups at 300 µs/item: capacity ~10k req/s vs 1.5k offered
    let n = 400;
    let trace = poisson(n, 1_500.0, 11);
    for (policy, exact, pname) in policies(3) {
        let plan = Deployment::replicated(3)
            .with_policy(policy)
            .with_batcher(BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(1) })
            .with_queue_depth(n)
            .with_window(2);
        let (srv, sim) = run_pair(plan, Duration::from_micros(300), &trace);
        assert_pair(&format!("flat/{pname}"), n, exact, &srv, &sim);
    }
}

#[test]
fn differential_single_chain() {
    // one 3-stage chain at 200 µs/stage: capacity 5k req/s vs 1.2k offered
    let n = 360;
    let trace = poisson(n, 1_200.0, 12);
    for (policy, _, pname) in policies(1) {
        let plan = Deployment::chain(3)
            .with_policy(policy)
            .with_batcher(BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(1) })
            .with_queue_depth(n)
            .with_window(2);
        let (srv, sim) = run_pair(plan, Duration::from_micros(200), &trace);
        // a single group makes every policy's dispatch trivially exact
        assert_pair(&format!("chain/{pname}"), n, true, &srv, &sim);
    }
}

#[test]
fn differential_replicated_chains() {
    // 2 chains x 2 stages at 250 µs/stage: capacity 8k req/s vs 1.5k
    let n = 400;
    let trace = poisson(n, 1_500.0, 13);
    for (policy, exact, pname) in policies(2) {
        let plan = Deployment::replicated_chains(2, 2)
            .with_policy(policy)
            .with_batcher(BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(1) })
            .with_queue_depth(n)
            .with_window(2);
        let (srv, sim) = run_pair(plan, Duration::from_micros(250), &trace);
        assert_pair(&format!("repchain/{pname}"), n, exact, &srv, &sim);
    }
}

#[test]
fn same_seed_same_trace_is_bit_identical() {
    // seeds drawn by the property harness; each case runs the identical
    // autoscaled + SLO-tuned sim three times — twice here, once on a
    // fresh OS thread — and demands bit-equality of the order hash, the
    // summary and the control-event journal
    prop::check(
        0xF1EE7,
        5,
        |r: &mut Rng| r.next_u64(),
        |&seed| {
            let run = move || {
                let trace = diurnal(1_500, 300.0, 1_500.0, 2.0, seed);
                let plan = Deployment::replicated_chains(1, 2)
                    .with_policy(Policy::RoundRobin)
                    .with_batcher(BatcherConfig {
                        max_batch: 4,
                        max_wait: Duration::from_millis(1),
                    })
                    .with_queue_depth(16)
                    .with_window(2);
                let control = SimControl {
                    tick: Duration::from_millis(20),
                    signal: SignalConfig { window_ticks: 2 },
                    autoscaler: Some(AutoscalerConfig {
                        min_groups: 1,
                        max_groups: 3,
                        shed_out: 0.02,
                        p99_out_ms: f64::INFINITY,
                        util_in: 0.3,
                        cooldown_ticks: 2,
                        step: 1,
                    }),
                    slo: Some(SloConfig { p99_budget_ms: 8.0, ..SloConfig::default() }),
                    trailing_ticks: 6,
                };
                let cfg = SimConfig {
                    input_len: 4,
                    seed,
                    control: Some(control),
                    ..SimConfig::default()
                };
                let rep = FleetSim::uniform_with_standby(
                    plan,
                    mock_sim(Duration::from_micros(800)),
                    2,
                    cfg,
                )
                .run(&trace);
                (
                    rep.order_hash,
                    rep.events_processed,
                    format!("{:?}", rep.summary),
                    format!("{:?}", rep.events),
                )
            };
            let a = run();
            let b = run();
            let c = std::thread::spawn(run).join().expect("sim thread");
            if a != b {
                return Err(format!(
                    "seed {seed:#x}: two in-thread runs diverged \
                     (hash {:#x} vs {:#x})",
                    a.0, b.0
                ));
            }
            if a != c {
                return Err(format!(
                    "seed {seed:#x}: cross-thread run diverged \
                     (hash {:#x} vs {:#x})",
                    a.0, c.0
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn random_topologies_preserve_invariants() {
    prop::check(
        0xBEEF,
        40,
        |r: &mut Rng| r.next_u64(),
        |&seed| {
            let mut r = Rng::new(seed ^ 0x9E37_79B9_7F4A_7C15);
            let groups = 1 + r.below(4) as usize;
            let stages = 1 + r.below(3) as usize;
            let queue_depth = 1 + r.below(8) as usize;
            let window = 1 + r.below(3) as usize;
            let max_batch = 1 + r.below(6) as usize;
            let max_wait = Duration::from_micros(r.below(2_000));
            let per_item = Duration::from_micros(50 + r.below(450));
            let policy = match r.below(3) {
                0 => Policy::RoundRobin,
                1 => Policy::JoinShortestQueue,
                _ => Policy::Weighted(vec![1.0; groups]),
            };
            let backend = if r.chance(0.5) {
                SimBackend::Mock { base: Duration::from_micros(r.below(100)), per_item }
            } else {
                SimBackend::Pipelined {
                    xfer_per_item: per_item.mul_f64(0.5),
                    compute_per_item: per_item.mul_f64(0.5),
                }
            };
            let control = r.chance(0.5).then(|| SimControl {
                tick: Duration::from_millis(1 + r.below(30)),
                signal: SignalConfig { window_ticks: 1 + r.below(4) as usize },
                autoscaler: r.chance(0.7).then(|| AutoscalerConfig {
                    min_groups: 1,
                    max_groups: groups + 2,
                    shed_out: 0.02,
                    p99_out_ms: f64::INFINITY,
                    util_in: 0.3,
                    cooldown_ticks: 1 + r.below(3) as usize,
                    step: 1,
                }),
                slo: r
                    .chance(0.5)
                    .then(|| SloConfig { p99_budget_ms: 4.0, ..SloConfig::default() }),
                trailing_ticks: r.below(6) as usize,
            });
            let standby = if control.is_some() { r.below(3) as usize } else { 0 };
            let n = 50 + r.below(350) as usize;
            let rate = 200.0 + r.below(4_000) as f64;
            let trace = bursty(n, rate, rate * 6.0, 24, seed);

            let plan = Deployment::replicated_chains(groups, stages)
                .with_policy(policy)
                .with_batcher(BatcherConfig { max_batch, max_wait })
                .with_queue_depth(queue_depth)
                .with_window(window);
            let cfg = SimConfig { input_len: 4, seed, control, ..SimConfig::default() };
            // timestamp monotonicity and exactly-once completion are
            // panics inside the sim; the checks below are the
            // conservation laws the report must satisfy
            let rep = FleetSim::uniform_with_standby(plan, backend, standby, cfg).run(&trace);

            if rep.submitted + rep.shed != n {
                return Err(format!(
                    "offered {} != accepted {} + shed {}",
                    n, rep.submitted, rep.shed
                ));
            }
            if rep.completed != rep.submitted {
                return Err(format!(
                    "accepted {} but completed {}",
                    rep.submitted, rep.completed
                ));
            }
            if rep.max_queue_seen > queue_depth {
                return Err(format!(
                    "queue occupancy {} exceeded bound {}",
                    rep.max_queue_seen, queue_depth
                ));
            }
            if rep.submitted == 0 {
                return Err("first arrival into an empty fleet can never shed".into());
            }
            if rep.summary.submitted != rep.submitted || rep.summary.shed != rep.shed {
                return Err("summary counters disagree with the report".into());
            }
            if rep.summary.fleet.is_none() {
                return Err("completions recorded but fleet summary empty".into());
            }
            if rep.events_processed == 0 {
                return Err("event loop processed nothing".into());
            }
            Ok(())
        },
    );
}
