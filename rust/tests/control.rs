//! Control-plane acceptance: (a) the autoscaler absorbs a flash crowd
//! that a static fleet sheds, scaling out within cooldown bounds and back
//! in afterwards; (b) the SLO controller brings p99 under budget on a
//! backlogged worker without giving up steady-state throughput; (c)
//! losing a device of a sharded plan triggers re-partition onto the
//! survivor — migrating cached packed manifests with zero re-packs when
//! the cache is warm — or a clean infeasibility report, and the repaired
//! plan splices into a running chain; (d) scaling works in whole chain
//! groups of the `Deployment` topology, never lone mid-chain workers;
//! plus packing-cache behavior under control-plane churn and the
//! on-disk `ControlEvent` journal round-trip.

use std::sync::Arc;
use std::time::Duration;

use fcmp::control::{
    load_events, replan, run_loop, save_events, splice_mock_chain, AutoscalerConfig,
    ControlEventKind, ControlledFleet, FailureEvent, LoopConfig, SignalConfig, SloConfig,
};
use fcmp::coordinator::{
    flash_crowd, poisson, shard_service_times, BatcherConfig, Deployment, MockBackend,
    ReplicaSpec, Server, WorkerId,
};
use fcmp::device::{zynq_7012s, zynq_7020};
use fcmp::nn::{cnv, CnvVariant};
use fcmp::report::pack_network_cached;
use fcmp::sharding::{fits_packed, partition, PartitionConfig};

fn specs_7020(k: usize) -> Vec<ReplicaSpec> {
    (0..k).map(|_| ReplicaSpec::paper_point(zynq_7020())).collect()
}

/// (a) Flash crowd: scale-out within cooldown bounds, shed rate below the
/// static fleet of the initial size, scale back in over the quiet tail.
#[test]
fn autoscaler_absorbs_a_flash_crowd_a_static_fleet_sheds() {
    let net = cnv(CnvVariant::W1A1);
    // base 200 req/s, 5x burst over [0.5, 1.0), ~1 s quiet tail; one
    // group sustains 500 req/s (2 ms/item), so the burst needs ~2-3
    let trace = flash_crowd(800, 200.0, 5.0, 0.5, 0.5, 7);
    let batcher = BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(1) };
    let service_us = 2_000.0;
    let cooldown = 2usize;
    let base_cfg = LoopConfig {
        tick: Duration::from_millis(20),
        signal: SignalConfig { window_ticks: 2 },
        trailing_ticks: 10,
        input_len: 4,
        seed: 7,
        ..LoopConfig::default()
    };

    // static arm: 1 group, no controller
    let mut static_fleet =
        ControlledFleet::start(net.clone(), specs_7020(1), vec![], service_us, batcher, 32);
    let static_rep = run_loop(&mut static_fleet, &trace, &base_cfg);
    static_fleet.shutdown();

    // autoscaled arm: same initial size, 3 standby devices
    let mut auto_fleet =
        ControlledFleet::start(net, specs_7020(1), specs_7020(3), service_us, batcher, 32);
    let auto_cfg = LoopConfig {
        autoscaler: Some(AutoscalerConfig {
            min_groups: 1,
            max_groups: 4,
            shed_out: 0.02,
            p99_out_ms: f64::INFINITY,
            util_in: 0.2,
            cooldown_ticks: cooldown,
            step: 1,
        }),
        ..base_cfg
    };
    let auto_rep = run_loop(&mut auto_fleet, &trace, &auto_cfg);
    auto_fleet.shutdown();

    // the static baseline must actually have been overloaded, or the
    // comparison is vacuous
    assert!(
        static_rep.shed > 0,
        "static fleet absorbed the whole burst — the scenario lost its signal"
    );
    assert!(auto_rep.scale_outs() >= 1, "no scale-out under a 5x flash crowd");
    assert!(
        auto_rep.max_groups_seen > auto_rep.initial_groups,
        "fleet never grew: {:?}",
        auto_rep.events
    );
    // scale decisions respect the cooldown: consecutive scale events are
    // at least `cooldown` ticks apart
    let ticks = auto_rep.scale_ticks();
    for w in ticks.windows(2) {
        assert!(
            w[1] - w[0] >= cooldown,
            "scale events at ticks {:?} violate the {cooldown}-tick cooldown",
            ticks
        );
    }
    // the burst absorbed: strictly less shed than the static fleet
    assert!(
        auto_rep.shed < static_rep.shed,
        "autoscaled shed {} >= static shed {}",
        auto_rep.shed,
        static_rep.shed
    );
    assert!(
        auto_rep.shed_rate() < static_rep.shed_rate(),
        "autoscaled shed rate {:.3} >= static {:.3}",
        auto_rep.shed_rate(),
        static_rep.shed_rate()
    );
    // and the quiet tail scales the fleet back in
    assert!(auto_rep.scale_ins() >= 1, "no scale-in over the quiet tail: {:?}", auto_rep.events);
    assert!(
        auto_rep.final_groups < auto_rep.max_groups_seen,
        "fleet ended at its peak size {}",
        auto_rep.final_groups
    );
    // every journaled event timestamps its position in the run
    assert!(auto_rep.events.iter().all(|e| e.at_s >= 0.0 && e.at_s.is_finite()));
}

/// (d) Group-granular scaling (acceptance): on a fleet of 2-stage chain
/// groups, the autoscaler adds and retires whole groups — devices move
/// in multiples of the chain depth and no partial chain ever serves.
#[test]
fn autoscaler_scales_whole_chain_groups_not_lone_replicas() {
    let net = cnv(CnvVariant::W1A1);
    // one active 2-stage group; 5 standby devices fund at most two more
    // whole groups (the 5th device can never serve alone). Each stage
    // serves in 1 ms (2 ms device service / 2 stages), so one group
    // sustains ~1000 req/s; the 4x burst over 350 req/s needs a second
    // group.
    let trace = flash_crowd(900, 350.0, 4.0, 0.4, 0.5, 13);
    let batcher = BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(1) };
    let mut fleet = ControlledFleet::start_chained(
        net,
        vec![specs_7020(2)],
        specs_7020(5),
        2_000.0,
        batcher,
        32,
    );
    assert_eq!((fleet.group_count(), fleet.stages()), (1, 2));
    let cfg = LoopConfig {
        tick: Duration::from_millis(20),
        signal: SignalConfig { window_ticks: 2 },
        autoscaler: Some(AutoscalerConfig {
            min_groups: 1,
            max_groups: 3,
            shed_out: 0.02,
            p99_out_ms: f64::INFINITY,
            util_in: 0.2,
            cooldown_ticks: 2,
            step: 1,
        }),
        trailing_ticks: 10,
        input_len: 4,
        seed: 13,
        ..LoopConfig::default()
    };
    let rep = run_loop(&mut fleet, &trace, &cfg);
    let final_groups = fleet.group_count();
    let final_standby = fleet.standby_len();
    fleet.shutdown();

    assert!(rep.scale_outs() >= 1, "no scale-out under a 4x flash crowd: {:?}", rep.events);
    assert!(rep.max_groups_seen >= 2, "fleet never added a chain group");
    // the devices moved in whole-group multiples of the chain depth:
    // active + standby always partitions the original 7-device pool with
    // active a multiple of 2
    assert_eq!(final_groups * 2 + final_standby, 7);
    // every scale event is a whole-group delta
    for e in &rep.events {
        match e.kind {
            ControlEventKind::ScaleOut { from, to } => assert!(to > from),
            ControlEventKind::ScaleIn { from, to } => assert!(to < from),
            _ => {}
        }
    }
    // the quiet tail folds back toward one group
    assert!(rep.scale_ins() >= 1, "no scale-in over the quiet tail: {:?}", rep.events);
    assert_eq!(rep.completed, rep.submitted, "accepted requests must drain");
}

/// The journal of a real controlled run round-trips through disk in the
/// trace-file convention (satellite: control-plane persistence).
#[test]
fn control_event_journal_roundtrips_for_a_real_run() {
    let net = cnv(CnvVariant::W1A1);
    let trace = flash_crowd(500, 250.0, 5.0, 0.3, 0.4, 31);
    let batcher = BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(1) };
    let mut fleet =
        ControlledFleet::start(net, specs_7020(1), specs_7020(2), 2_000.0, batcher, 32);
    let cfg = LoopConfig {
        tick: Duration::from_millis(20),
        signal: SignalConfig { window_ticks: 2 },
        autoscaler: Some(AutoscalerConfig {
            min_groups: 1,
            max_groups: 3,
            shed_out: 0.02,
            p99_out_ms: f64::INFINITY,
            util_in: 0.2,
            cooldown_ticks: 2,
            step: 1,
        }),
        trailing_ticks: 8,
        input_len: 4,
        seed: 31,
        ..LoopConfig::default()
    };
    let rep = run_loop(&mut fleet, &trace, &cfg);
    fleet.shutdown();
    assert!(!rep.events.is_empty(), "the burst must produce journalable events");

    let path = std::env::temp_dir().join("fcmp_control_journal_test.txt");
    save_events(&rep.events, &path).unwrap();
    let back = load_events(&path).unwrap();
    assert_eq!(back.len(), rep.events.len());
    for (a, b) in rep.events.iter().zip(&back) {
        assert_eq!(a.tick, b.tick);
        assert_eq!(a.kind, b.kind);
        assert!((a.at_s - b.at_s).abs() < 1e-6);
    }
    // journal times are monotone like a trace's arrivals
    assert!(back.windows(2).all(|w| w[1].at_s >= w[0].at_s));
    let _ = std::fs::remove_file(&path);
}

/// (b) SLO batching: an over-wide batching window inflates p99 far past
/// the budget; the controller shrinks it until p99 is inside the budget,
/// and steady-state throughput stays within 5% of the uncontrolled fleet.
#[test]
fn slo_controller_brings_p99_under_budget_without_throughput_loss() {
    let net = cnv(CnvVariant::W1A1);
    // 80 ms window, batch cap 64: arrivals at 300/s ride ~24-request
    // batches that close on the window — p99 lands near 80 ms against a
    // 35 ms budget, while capacity (0.5 ms/item) is nowhere near the limit
    let bad_batcher = BatcherConfig { max_batch: 64, max_wait: Duration::from_millis(80) };
    let budget_ms = 35.0;
    let slo = SloConfig {
        p99_budget_ms: budget_ms,
        min_wait: Duration::from_millis(1),
        max_wait: Duration::from_millis(80),
        min_batch: 1,
        max_batch: 64,
        grow_below: 0.4,
    };
    let mk_fleet = || {
        ControlledFleet::start(
            net.clone(),
            specs_7020(1),
            vec![],
            500.0,
            bad_batcher,
            256,
        )
    };
    let base_cfg = LoopConfig {
        tick: Duration::from_millis(30),
        signal: SignalConfig { window_ticks: 2 },
        trailing_ticks: 2,
        input_len: 4,
        seed: 11,
        ..LoopConfig::default()
    };
    let warm = poisson(400, 300.0, 11);
    let probe = poisson(300, 300.0, 12);

    // uncontrolled arm: probe straight through the backlogged window
    let mut static_fleet = mk_fleet();
    let static_rep = run_loop(&mut static_fleet, &probe, &base_cfg);
    static_fleet.shutdown();
    let static_fleet_summary = static_rep.summary.fleet.expect("static probe completions");
    assert!(
        static_fleet_summary.latency_ms.p99 > budget_ms,
        "uncontrolled p99 {:.1} ms already inside the {budget_ms} ms budget — \
         the scenario lost its signal",
        static_fleet_summary.latency_ms.p99
    );

    // controlled arm: converge on the warm trace, then measure the probe
    let slo_cfg = LoopConfig { slo: Some(slo), ..base_cfg };
    let mut fleet = mk_fleet();
    let warm_rep = run_loop(&mut fleet, &warm, &slo_cfg);
    assert!(
        warm_rep
            .events
            .iter()
            .any(|e| matches!(e.kind, ControlEventKind::SloAdjust { .. })),
        "controller never adjusted the batcher"
    );
    let probe_rep = run_loop(&mut fleet, &probe, &slo_cfg);
    fleet.shutdown();
    let controlled = probe_rep.summary.fleet.expect("controlled probe completions");
    assert!(
        controlled.latency_ms.p99 < budget_ms,
        "p99 {:.1} ms still over the {budget_ms} ms budget after convergence",
        controlled.latency_ms.p99
    );
    // steady-state throughput within 5%: both arms are arrival-bound, the
    // controller must not have turned latency into lost completions
    assert_eq!(probe_rep.completed, probe_rep.submitted, "controlled arm dropped requests");
    assert!(
        controlled.throughput_fps >= 0.95 * static_fleet_summary.throughput_fps,
        "throughput {:.0} fps fell more than 5% below the uncontrolled {:.0} fps",
        controlled.throughput_fps,
        static_fleet_summary.throughput_fps
    );
}

/// (c) Device loss on a 2-device sharded plan: re-partition onto the
/// survivor with ZERO re-packs when the cache already holds the
/// surviving point, and splice the repaired plan into a running chain.
#[test]
fn device_loss_repartitions_onto_survivor_migrating_cached_manifests() {
    let net = cnv(CnvVariant::W1A1);
    let devs = [zynq_7020(), zynq_7012s()];
    // distinctive seed so no other test shares these cache keys
    let cfg = PartitionConfig { generations: 0, seed: 777_001, ..PartitionConfig::default() };

    let plan = partition(&net, &devs, cfg).expect("2-shard plan");
    assert_eq!(plan.shards.len(), 2);
    // the deployment-time feasibility probe warms the survivor's
    // full-range packed point — exactly what repair will need
    assert!(fits_packed(&net, &devs[0], cfg), "W1A1 must fit a 7020 packed");

    // serve the plan as a 2-stage chain group
    let svc: Vec<Duration> = shard_service_times(&plan)
        .iter()
        .map(|d| Duration::from_micros((d.as_micros() as u64).clamp(50, 500)))
        .collect();
    let batcher = BatcherConfig { max_batch: 1, max_wait: Duration::from_millis(1) };
    let dep = Deployment::chain(plan.shards.len())
        .with_batcher(batcher)
        .with_queue_depth(16);
    let mut srv = Server::deploy(
        move |id: WorkerId| MockBackend::with_service(Duration::ZERO, svc[id.stage]),
        dep,
    );
    for i in 0..20u64 {
        srv.submit_blocking(i, vec![i as f32]).unwrap();
    }

    // device 1 dies: re-plan over the survivor
    let out = replan(&net, &devs, 1, cfg);
    assert_eq!(out.survivors.len(), 1);
    assert_eq!(out.survivors[0].name, "zynq-7020");
    let new_plan = out.plan.as_ref().expect("survivor hosts the full network");
    assert_eq!(new_plan.shards.len(), 1);
    assert_eq!(
        (out.migrated_shards, out.repacked_shards),
        (1, 0),
        "warm cache must migrate the manifest, not re-pack"
    );

    // splice the repaired plan into the running server and keep serving
    splice_mock_chain(&mut srv, new_plan, batcher, 16, Duration::from_millis(2)).unwrap();
    assert_eq!(srv.group_count(), 1);
    assert_eq!(srv.replica_count(), 1);
    // the spliced stage is the bottleneck of its own 1-stage chain, so
    // co-tuning must have set it to serve greedily (batch 1, no window)
    let spliced = srv.batcher_config(0, 0).expect("spliced stage");
    assert_eq!(spliced.max_batch, 1);
    assert_eq!(spliced.max_wait, Duration::ZERO);
    for i in 100..120u64 {
        srv.submit_blocking(i, vec![i as f32]).unwrap();
    }
    srv.shutdown();
    let (mut pre, mut post) = (0, 0);
    while let Some(c) = srv.next_completion() {
        if c.id < 100 {
            // old 2-stage chain: each forward adds +1 to the mock sum
            assert_eq!(c.output[0], c.id as f32 + 1.0, "frame {} broke pre-swap", c.id);
            pre += 1;
        } else {
            // repaired single-shard chain: output == input
            assert_eq!(c.output[0], c.id as f32, "frame {} broke post-swap", c.id);
            post += 1;
        }
    }
    assert_eq!((pre, post), (20, 20), "drain-and-swap dropped frames");
}

/// (c, infeasible half) When the survivors cannot host the network, the
/// repair reports cleanly instead of producing a plan (or panicking).
#[test]
fn device_loss_with_infeasible_survivors_reports_cleanly() {
    let net = cnv(CnvVariant::W2A2);
    let devs = [zynq_7012s(), zynq_7012s()];
    let cfg = PartitionConfig { generations: 0, seed: 777_002, ..PartitionConfig::default() };
    // sanity: the 2-device plan exists...
    assert!(partition(&net, &devs, cfg).is_ok());
    // ...but one 7012S cannot host W2A2 even packed
    let out = replan(&net, &devs, 0, cfg);
    assert!(!out.is_feasible());
    assert_eq!(out.survivors.len(), 1);
    let reason = out.infeasible.expect("infeasibility reason");
    assert!(
        reason.contains("OCM") || reason.contains("partition"),
        "unhelpful infeasibility report: {reason}"
    );
    assert_eq!((out.migrated_shards, out.repacked_shards), (0, 0));
}

/// Packing cache under control-plane churn: the same (network, device,
/// H_B, engine, seed) point requested concurrently from the repair path
/// (sliced network) and the scale-out path (full network) converges on
/// one cached design per key — no duplicate growth, deterministic hits.
#[test]
fn packing_cache_churn_converges_on_one_design_per_key() {
    let net = cnv(CnvVariant::W1A1);
    let dev = zynq_7020();
    let n = net.stages.len();
    let seed = 909_090u64; // distinctive: no other test shares these keys

    // 4 concurrent "scale-out" fetches (full net) + 4 concurrent
    // "repair" fetches (full-range slice, the k=1 partition's key)
    let sliced = net.slice(0, n);
    let (full_arcs, slice_arcs) = std::thread::scope(|s| {
        let full: Vec<_> =
            (0..4).map(|_| s.spawn(|| pack_network_cached(&net, &dev, 4, 0, seed))).collect();
        let slice: Vec<_> = (0..4)
            .map(|_| s.spawn(|| pack_network_cached(&sliced, &dev, 4, 0, seed)))
            .collect();
        (
            full.into_iter().map(|h| h.join().unwrap()).collect::<Vec<_>>(),
            slice.into_iter().map(|h| h.join().unwrap()).collect::<Vec<_>>(),
        )
    });
    for a in &full_arcs[1..] {
        assert!(Arc::ptr_eq(&full_arcs[0], a), "racing full-net fetches diverged");
    }
    for a in &slice_arcs[1..] {
        assert!(Arc::ptr_eq(&slice_arcs[0], a), "racing slice fetches diverged");
    }
    // bounded growth: repeated requests keep hitting the same designs
    for _ in 0..5 {
        assert!(Arc::ptr_eq(&full_arcs[0], &pack_network_cached(&net, &dev, 4, 0, seed)));
        assert!(Arc::ptr_eq(&slice_arcs[0], &pack_network_cached(&sliced, &dev, 4, 0, seed)));
    }
    // keyed-hit determinism: the full net and its full-range slice are
    // distinct keys (the slice embeds the range in its name) yet pack to
    // the same BRAM cost — same buffers, same engine, same seed
    assert!(!Arc::ptr_eq(&full_arcs[0], &slice_arcs[0]));
    assert_eq!(full_arcs[0].report.brams, slice_arcs[0].report.brams);
}

/// Failure injection through the driver loop: the scheduled kill fires,
/// the journal records it, and the autoscaler refills the fleet from
/// standby.
#[test]
fn failure_injection_is_journaled_and_recovered_from() {
    let net = cnv(CnvVariant::W1A1);
    // steady 700 req/s saturates one 500 req/s group but not two;
    // killing one at 0.3 s forces sheds, and the autoscaler pulls the
    // standby device in
    let trace = poisson(600, 700.0, 23);
    let batcher = BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(1) };
    let mut fleet =
        ControlledFleet::start(net, specs_7020(2), specs_7020(1), 2_000.0, batcher, 16);
    let cfg = LoopConfig {
        tick: Duration::from_millis(20),
        signal: SignalConfig { window_ticks: 2 },
        autoscaler: Some(AutoscalerConfig {
            min_groups: 1,
            max_groups: 3,
            shed_out: 0.02,
            p99_out_ms: f64::INFINITY,
            util_in: 0.0, // scale-in disabled: the kill target must exist
            cooldown_ticks: 2,
            step: 1,
        }),
        failures: vec![FailureEvent { at_s: 0.3, group: 1 }],
        trailing_ticks: 4,
        input_len: 4,
        seed: 23,
        ..LoopConfig::default()
    };
    let rep = run_loop(&mut fleet, &trace, &cfg);
    fleet.shutdown();
    assert_eq!(rep.failures(), 1, "the scheduled kill must fire: {:?}", rep.events);
    let failure_pos = rep
        .events
        .iter()
        .position(|e| matches!(e.kind, ControlEventKind::Failure { .. }))
        .unwrap();
    assert!(
        rep.events[failure_pos..]
            .iter()
            .any(|e| matches!(e.kind, ControlEventKind::ScaleOut { .. })),
        "no scale-out after the failure: {:?}",
        rep.events
    );
    assert_eq!(rep.completed, rep.submitted, "accepted requests must survive the churn");
}
