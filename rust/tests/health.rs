//! Fleet-health integration tests: long-sweep determinism (same seed ⇒
//! identical event order, health journal, and incident table), incident
//! attribution against the control-event journal in both the static and
//! autoscaled regimes, journal persistence round-tripping through the
//! JSONL sink, and the zero-allocation steady state with health
//! collection enabled on the threaded server.

use std::path::PathBuf;
use std::time::Duration;

use fcmp::control::{AutoscalerConfig, ControlEventKind, SignalConfig};
use fcmp::coordinator::{uniform, BatcherConfig, Deployment, MockBackend, Policy, Server};
use fcmp::obs::health::{correlate, stats};
use fcmp::obs::{HealthConfig, HealthJournal, ObsConfig, SeriesConfig};
use fcmp::sim::{FleetSim, SimBackend, SimConfig, SimControl, SimReport};

/// One chain group serves 50 req/s (20 ms/item, service is
/// batch-size-invariant with `base = 0`), so 125 req/s offered overruns
/// one group 2.5x but fits under the 3-group ceiling (150 req/s).
const PER_ITEM: Duration = Duration::from_millis(20);
const OFFERED_HZ: f64 = 125.0;
const HORIZON_REQS: usize = 7_500; // 60 virtual seconds at 125 req/s

fn tmp(tag: &str) -> PathBuf {
    let p = std::env::temp_dir().join(format!("fcmp-health-{tag}-{}.jsonl", std::process::id()));
    let _ = std::fs::remove_file(&p);
    p
}

/// Second-resolution cells persisted every second, burn windows
/// compressed 100x (page 36 s / 3 s, ticket 216 s / 18 s) so the whole
/// alert lifecycle fits in a 60-second virtual horizon.
fn fast_health(out: Option<PathBuf>) -> HealthConfig {
    HealthConfig {
        sample_s: 1.0,
        window_scale: 0.01,
        series: SeriesConfig { resolutions: vec![(1.0, 600)], persist_res_s: 1.0 },
        out,
        ..HealthConfig::default()
    }
}

/// One-second control ticks. The 20-tick cooldown holds the second
/// scale-out back until t ≈ 23 s, so the fleet sheds 20% for most of the
/// ticket alert's life — mitigation lands *inside* the breach window.
/// `util_in = 0` disables scale-in: the capacity story stays monotone.
fn auto_control() -> SimControl {
    SimControl {
        tick: Duration::from_secs(1),
        signal: SignalConfig { window_ticks: 3 },
        autoscaler: Some(AutoscalerConfig {
            min_groups: 1,
            max_groups: 3,
            shed_out: 0.02,
            p99_out_ms: f64::INFINITY,
            util_in: 0.0,
            cooldown_ticks: 20,
            step: 1,
        }),
        slo: None,
        trailing_ticks: 8,
    }
}

fn run_overload(control: Option<SimControl>, standby: usize, out: Option<PathBuf>) -> SimReport {
    let plan = Deployment::replicated(1)
        .with_policy(Policy::RoundRobin)
        .with_batcher(BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(10) })
        .with_queue_depth(16)
        .with_window(1);
    let cfg = SimConfig {
        seed: 9,
        control,
        health: Some(fast_health(out)),
        ..SimConfig::default()
    };
    FleetSim::uniform_with_standby(
        plan,
        SimBackend::Mock { base: Duration::ZERO, per_item: PER_ITEM },
        standby,
        cfg,
    )
    .run(&uniform(HORIZON_REQS, OFFERED_HZ))
}

/// The long-sweep determinism contract: two runs of the same seeded
/// overload through the autoscaled fleet must agree on the event-order
/// fingerprint, the entire health journal (every downsampled cell and
/// alert transition), and the derived incident table.
#[test]
fn seeded_health_sweep_is_deterministic() {
    let a = run_overload(Some(auto_control()), 2, None);
    let b = run_overload(Some(auto_control()), 2, None);
    assert_eq!(a.order_hash, b.order_hash, "event order diverged across identical runs");

    let ja = a.health.expect("health was configured");
    let jb = b.health.expect("health was configured");
    assert!(!ja.cells.is_empty(), "a 60 s overload must journal downsampled cells");
    assert!(!ja.alerts.is_empty(), "a 2.5x overload must trip the burn alerts");
    assert_eq!(ja, jb, "health journals diverged across identical runs");

    let ia = correlate(&ja, &a.events);
    let ib = correlate(&jb, &b.events);
    assert!(!ia.is_empty());
    assert_eq!(ia, ib, "incident tables diverged across identical runs");
}

/// A frozen 1-group fleet under the same overload: no control plane, so
/// every incident must come back unresponded and still firing, and the
/// shed burn alert must have both tiers open. The health ticks here are
/// paced by the sample interval alone (no control tick to ride).
#[test]
fn static_fleet_incidents_are_unresponded() {
    let out = tmp("static");
    let rep = run_overload(None, 0, Some(out.clone()));
    assert!(rep.shed > 0, "2.5x overload of a frozen fleet must shed");
    assert!(rep.events.is_empty(), "no control plane, no control events");

    let j = rep.health.expect("health was configured");
    let shed_cells = j.cells.iter().filter(|c| c.series.name() == "shed").count();
    assert!(shed_cells >= 30, "60 s at 1 s persist cells must journal a shed series");

    let incidents = correlate(&j, &rep.events);
    let st = stats(&incidents);
    assert_eq!(st.incidents, 2, "shed page + shed ticket must both fire once: {incidents:?}");
    assert_eq!(st.unresponded, st.incidents);
    assert_eq!(st.mitigated, 0);
    for i in &incidents {
        assert!(i.cleared_s.is_none(), "sustained overload must never clear: {i:?}");
        assert!(i.response.is_none() && i.ttm_s.is_none() && !i.mitigated);
        assert!(i.fired_s >= i.breach_start_s && i.ttd_s >= 0.0);
    }

    // the streamed JSONL journal must round-trip to the in-memory one
    let loaded = HealthJournal::load(&out).expect("journal must parse back");
    assert_eq!(loaded, j, "JSONL round-trip lost or mangled journal lines");
    let _ = std::fs::remove_file(&out);
}

/// The autoscaled fleet under the same overload: the scaler steps
/// 1 → 2 → 3 groups (the cooldown delaying the second step), the burn
/// alerts fire during the breach and clear once capacity covers the
/// offered load, and every incident is attributed to a scale-out that
/// landed inside its breach window.
#[test]
fn autoscaler_response_lands_inside_breach_window() {
    let rep = run_overload(Some(auto_control()), 2, None);
    assert_eq!(rep.max_groups_seen, 3, "the scaler must step out to the 3-group ceiling");
    assert!(
        rep.events.iter().any(|e| matches!(e.kind, ControlEventKind::ScaleOut { .. })),
        "no scale-out in the control journal: {:?}",
        rep.events
    );

    let j = rep.health.expect("health was configured");
    let incidents = correlate(&j, &rep.events);
    let st = stats(&incidents);
    assert!(st.incidents >= 2, "shed page + ticket must both fire: {incidents:?}");
    assert_eq!(st.mitigated, st.incidents, "every incident must be mitigated: {incidents:?}");
    assert_eq!(st.unresponded, 0);
    assert!(st.mean_ttd_s >= 0.0 && st.mean_ttm_s >= 0.0);
    for i in &incidents {
        assert!(i.cleared_s.is_some(), "scaled capacity must clear the alert: {i:?}");
        let resp = i.response_at_s.expect("every incident must have a response");
        assert!(
            resp + 1e-9 >= i.breach_start_s && resp <= i.cleared_s.unwrap(),
            "response must land inside the breach window: {i:?}"
        );
        assert!(i.response.as_deref().unwrap().starts_with("scale-out"), "{i:?}");
        assert!(i.ttm_s.unwrap() >= 0.0);
    }
}

/// Health collection must not break the asserted zero-allocation steady
/// state: the monitor samples on the snapshot path (building the merged
/// fleet histogram between samples only), never on the per-request hot
/// path. Same setup as the tracing variant in `tests/obs.rs`, with the
/// health monitor armed at a 5 ms cadence.
#[test]
fn steady_state_stays_allocation_free_with_health() {
    let input_len = 8;
    let mut srv = Server::deploy_with_obs(
        |_| MockBackend::instant(),
        Deployment::replicated(2)
            .with_policy(Policy::RoundRobin)
            .with_batcher(BatcherConfig { max_batch: 4, max_wait: Duration::from_micros(200) })
            .with_queue_depth(32),
        &ObsConfig { sample: 0.01, ..ObsConfig::default() },
    );
    srv.set_health(HealthConfig {
        sample_s: 0.005,
        series: SeriesConfig { resolutions: vec![(0.01, 1024)], persist_res_s: 0.01 },
        ..HealthConfig::default()
    });
    srv.buffer_pool().prime(64, input_len);
    let fm = srv.replay(&uniform(300, 4000.0), input_len, 42);
    assert_eq!(fm.completed(), 300);
    let hot = fm.summary().hot;
    assert_eq!(hot.submits, 300);
    assert_eq!(hot.pool_misses, 0, "health sampling allocated on the submit path: {hot:?}");
    assert!(hot.pool_hits >= 300, "every request must draw from the pool: {hot:?}");
    let (_, span_misses) = srv.obs().span_pool_stats();
    assert_eq!(span_misses, 0, "span pool must be primed past steady-state concurrency");

    let j = srv.take_health().expect("health was configured");
    assert!(!j.cells.is_empty(), "the monitor must observe the replay");
    assert!(j.alerts.is_empty(), "an unloaded fleet must not trip burn alerts");
    srv.shutdown();
}
