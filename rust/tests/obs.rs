//! Observability integration tests: the differential server-vs-sim span
//! check (same seeded trace through the threaded `Server` and the
//! discrete-event `FleetSim` at 100% sampling must produce agreeing
//! per-stage critical-path breakdowns), the zero-allocation steady state
//! with tracing enabled, head-sampling determinism at the driver level,
//! and the anomaly flush capturing the offending span.

use std::path::{Path, PathBuf};
use std::time::Duration;

use fcmp::coordinator::{
    poisson, uniform, BatcherConfig, Deployment, MockBackend, Policy, Server,
};
use fcmp::obs::{tracereport, AnomalyConfig, ObsConfig, SpanEvent};
use fcmp::sim::{FleetSim, SimBackend, SimConfig};

fn tmp(tag: &str) -> PathBuf {
    let p = std::env::temp_dir().join(format!("fcmp-obs-{tag}-{}.jsonl", std::process::id()));
    let _ = std::fs::remove_file(&p);
    p
}

fn chain_plan(groups: usize, stages: usize) -> Deployment {
    Deployment::replicated_chains(groups, stages)
        .with_policy(Policy::RoundRobin)
        .with_batcher(BatcherConfig { max_batch: 2, max_wait: Duration::from_micros(500) })
        .with_queue_depth(64)
        .with_window(2)
}

/// The PR's acceptance check: one seeded trace, two time domains. The
/// threaded server stamps spans on the monotonic clock, the sim on its
/// virtual clock; with identical round-robin routing both must yield the
/// same (group, stage) cells with the same traversal counts, and the
/// per-span compute means must land in the same order of magnitude (the
/// mock backends sleep/advance the same nominal service interval, but
/// real sleeps overshoot under scheduler noise — hence the wide band).
#[test]
fn server_and_sim_span_breakdowns_agree() {
    let srv_path = tmp("srv");
    let sim_path = tmp("sim");
    let n = 48;
    let trace = poisson(n, 200.0, 7);
    let per_item = Duration::from_micros(150);

    let mut srv = Server::deploy_with_obs(
        move |_| MockBackend::with_service(Duration::ZERO, per_item),
        chain_plan(2, 2),
        &ObsConfig::sampled(1.0, &srv_path),
    );
    let fm = srv.replay(&trace, 8, 7);
    assert_eq!(fm.completed(), n, "no shedding expected at this rate");
    srv.shutdown();

    let cfg =
        SimConfig { seed: 7, obs: ObsConfig::sampled(1.0, &sim_path), ..SimConfig::default() };
    let rep = FleetSim::uniform_with_standby(
        chain_plan(2, 2),
        SimBackend::Mock { base: Duration::ZERO, per_item },
        0,
        cfg,
    )
    .run(&trace);
    assert_eq!(rep.completed, n);
    assert_eq!(rep.shed, 0);

    let srv_rep = tracereport::analyze(&tracereport::load(&srv_path).unwrap());
    let sim_rep = tracereport::analyze(&tracereport::load(&sim_path).unwrap());
    assert_eq!(srv_rep.completed, n, "100% sampling must trace every completion");
    assert_eq!(sim_rep.completed, n);
    assert_eq!(srv_rep.shed, 0);
    assert_eq!(sim_rep.shed, 0);

    // identical routing: same cells, same traversal counts
    let srv_cells: Vec<((u16, u16), u64)> =
        srv_rep.stages.iter().map(|(k, b)| (*k, b.n)).collect();
    let sim_cells: Vec<((u16, u16), u64)> =
        sim_rep.stages.iter().map(|(k, b)| (*k, b.n)).collect();
    assert_eq!(srv_cells, sim_cells, "drivers routed sampled spans differently");
    assert_eq!(srv_cells.len(), 4, "2 groups x 2 stages must all serve");

    // compute-segment agreement across time domains: the virtual driver
    // charges the exact nominal batch service, the real driver at least
    // that (sleeps only overshoot), bounded by a generous jitter factor
    for (cell, b) in &srv_rep.stages {
        let s = sim_rep.stages[cell];
        let real = b.compute_ns as f64 / b.n as f64;
        let virt = s.compute_ns as f64 / s.n as f64;
        assert!(virt > 0.0, "virtual compute must be charged at {cell:?}");
        assert!(
            real >= 0.5 * virt && real <= 50.0 * virt,
            "compute mean diverged at {cell:?}: real {real:.0} ns vs virtual {virt:.0} ns"
        );
    }

    let _ = std::fs::remove_file(&srv_path);
    let _ = std::fs::remove_file(&sim_path);
}

/// Tracing must not break the asserted zero-allocation steady state:
/// same setup as `steady_state_submit_path_allocates_nothing`, but with
/// the sampler armed at 1% (rings only). Both pools stay miss-free — the
/// request buffer pool and the span pool (primed at hub construction).
#[test]
fn steady_state_stays_allocation_free_with_tracing() {
    let input_len = 8;
    let mut srv = Server::deploy_with_obs(
        |_| MockBackend::instant(),
        Deployment::replicated(2)
            .with_policy(Policy::RoundRobin)
            .with_batcher(BatcherConfig { max_batch: 4, max_wait: Duration::from_micros(200) })
            .with_queue_depth(32),
        &ObsConfig { sample: 0.01, ..ObsConfig::default() },
    );
    srv.buffer_pool().prime(64, input_len);
    let fm = srv.replay(&uniform(300, 4000.0), input_len, 42);
    assert_eq!(fm.completed(), 300);
    let hot = fm.summary().hot;
    assert_eq!(hot.submits, 300);
    assert_eq!(hot.pool_misses, 0, "tracing at 1% allocated on the submit path: {hot:?}");
    assert!(hot.pool_hits >= 300, "every request must draw from the pool: {hot:?}");
    let (_, span_misses) = srv.obs().span_pool_stats();
    assert_eq!(span_misses, 0, "span pool must be primed past steady-state concurrency");
    srv.shutdown();
}

/// Head-based sampling is a pure function of (seed, id): two sim runs
/// with the same trace and seed must flush byte-identical span id sets,
/// and partial sampling must actually be partial.
#[test]
fn sampled_id_set_is_deterministic_for_a_seed() {
    let run = |path: &Path| -> Vec<u64> {
        let cfg = SimConfig {
            seed: 11,
            obs: ObsConfig { sample: 0.35, trace_out: Some(path.into()), ..ObsConfig::default() },
            ..SimConfig::default()
        };
        let rep = FleetSim::uniform_with_standby(
            chain_plan(2, 1),
            SimBackend::Mock { base: Duration::ZERO, per_item: Duration::from_micros(100) },
            0,
            cfg,
        )
        .run(&poisson(200, 2000.0, 11));
        assert_eq!(rep.completed, 200);
        let mut ids: Vec<u64> = tracereport::load(path).unwrap().iter().map(|s| s.id).collect();
        ids.sort_unstable();
        ids
    };
    let p1 = tmp("det1");
    let p2 = tmp("det2");
    let a = run(&p1);
    let b = run(&p2);
    assert_eq!(a, b, "same seed must sample the same request ids");
    assert!(!a.is_empty(), "35% sampling over 200 ids must catch some");
    assert!(a.len() < 200, "35% sampling must not trace everything");
    let _ = std::fs::remove_file(&p1);
    let _ = std::fs::remove_file(&p2);
}

/// A shed burst must flush the recorder mid-run, and the flushed file
/// must contain the span that was shed — terminal `Shed` stamp included
/// — not just the healthy history around it.
#[test]
fn anomaly_flush_captures_the_offending_span() {
    let path = tmp("anomaly");
    let mut srv = Server::deploy_with_obs(
        |_| MockBackend::with_service(Duration::from_millis(20), Duration::ZERO),
        Deployment::replicated(1)
            .with_policy(Policy::RoundRobin)
            .with_batcher(BatcherConfig { max_batch: 1, max_wait: Duration::from_micros(100) })
            .with_queue_depth(1),
        &ObsConfig {
            sample: 1.0,
            trace_out: Some(path.clone()),
            anomaly: AnomalyConfig { shed_burst: 1, ..AnomalyConfig::default() },
            ..ObsConfig::default()
        },
    );
    // 30 arrivals 0.1 ms apart into a depth-1 queue behind a 20 ms
    // server: most of the burst sheds
    let fm = srv.replay(&uniform(30, 10_000.0), 8, 3);
    assert!(fm.summary().shed > 0, "the burst must overflow the depth-1 queue");
    srv.shutdown();

    let text = std::fs::read_to_string(&path).unwrap();
    assert!(text.contains("\"flush\":\"shed-burst\""), "no shed-burst flush marker:\n{text}");
    let spans = tracereport::load(&path).unwrap();
    let shed_spans = spans
        .iter()
        .filter(|s| s.stamps().last().map(|st| st.kind) == Some(SpanEvent::Shed))
        .count();
    assert!(shed_spans > 0, "flushed trace must contain the shed span(s)");
    let rep = tracereport::analyze(&spans);
    assert!(rep.shed > 0);
    assert!(rep.completed > 0, "accepted requests must still trace to completion");
    let _ = std::fs::remove_file(&path);
}
