//! Cross-module integration tests: the full FCMP flow (network -> buffers
//! -> packing -> streamer feasibility -> timing -> throughput), the DSE
//! path, and the report layer that regenerates the paper's tables.

use fcmp::device;
use fcmp::folding;
use fcmp::gals::{Ratio, StreamerConfig, StreamerSim};
use fcmp::memory;
use fcmp::nn::{cnv, resnet50, CnvVariant};
use fcmp::packing::{ga, run_packer, Constraints};
use fcmp::report;
use fcmp::timing;

fn quick_ga(seed: u64) -> ga::Ga {
    ga::Ga::new(ga::GaParams { generations: 40, seed, ..ga::GaParams::cnv() })
}

#[test]
fn full_fcmp_flow_cnv_to_7012s() {
    // the paper's embedded-class port, end to end through the modules
    let net = cnv(CnvVariant::W1A1);
    let big = device::zynq_7020();
    let small = device::zynq_7012s();

    // 1. unpacked does not fit the small device
    let r = folding::network_resources(&net, &big);
    assert!(r.total_brams() > small.bram18);

    // 2. pack at H_B = 4
    let out = report::pack_network(&net, &big, &quick_ga(1), 4);
    assert!(out.report.brams < out.baseline_brams);
    assert!(out.report.efficiency > 0.8);

    // 3. the packed memory subsystem fits the small device
    assert!(out.report.brams + memory::activation_brams(&net) / 2 <= small.bram18);

    // 4. H_B=4 wants R_F=2; the streamer sustains it cycle-exactly
    let sim = StreamerSim::new(StreamerConfig::fig7a(4, 64, Ratio::two())).run(3_000);
    assert!(sim.min_rate() > 0.99);

    // 5. timing closes at 100/200 MHz on the monolithic part: dFPS = 0
    let t = timing::evaluate(&small, 0.9, 100.0, 2.0, 100.0);
    assert!(t.delta_fps_pct.abs() < 1e-9);
}

#[test]
fn full_fcmp_flow_rn50_u280_beats_folding() {
    let net = resnet50(1);
    let u280 = device::alveo_u280();
    let res = folding::network_resources(&net, &u280);

    let mut ga = report::default_ga(&net);
    ga.params.generations = 30;
    let out = report::pack_network(&net, &u280, &ga, 4);
    let lut_p4 = (res.luts + out.logic_kluts * 1e3 + u280.shell_luts as f64) / u280.luts as f64;
    let p4 = timing::evaluate(&u280, lut_p4, 200.0, 2.0, 200.0);

    let f2net = net.fold2();
    let rf2 = folding::network_resources(&f2net, &u280);
    let lut_f2 = (rf2.luts + u280.shell_luts as f64) / u280.luts as f64;
    let f2 = timing::evaluate(&u280, lut_f2, 200.0, 1.0, 200.0);

    let speedup = p4.effective_fc_mhz / (f2.effective_fc_mhz / 2.0);
    assert!((1.2..1.7).contains(&speedup), "speedup {speedup} (paper 1.38)");
}

#[test]
fn dse_then_pack_composes() {
    // start from a deliberately under-folded CNV, solve folding for the
    // 7020, then pack the solved design — packing must still validate
    let mut slow = cnv(CnvVariant::W1A1);
    for s in &mut slow.stages {
        if let fcmp::nn::Stage::Mvau(l) = s {
            l.pe = 1;
            l.simd = 1;
        }
    }
    let dev = device::zynq_7020();
    let solved = folding::solve(&slow, &dev, 0.7);
    let bufs = memory::weight_buffers(&solved, 1);
    let items = memory::all_columns(&bufs);
    let c = Constraints::new(4, false);
    let (p, r) = run_packer(&quick_ga(3), &items, &c);
    p.validate(&items, &c).unwrap();
    assert!(r.brams <= memory::direct_brams(&bufs));
}

#[test]
fn packed_weights_bits_conserved() {
    // packing moves buffers around but the payload bits are invariant
    let net = cnv(CnvVariant::W2A2);
    let bufs = memory::weight_buffers(&net, 1);
    let items = memory::all_columns(&bufs);
    let c = Constraints::new(3, false);
    let (p, _) = run_packer(&quick_ga(4), &items, &c);
    let packed_bits: u64 = p
        .bins
        .iter()
        .flat_map(|b| b.items.iter())
        .map(|&i| items[i].bits())
        .sum();
    assert_eq!(packed_bits, memory::total_bits(&bufs));
}

#[test]
fn report_tables_well_formed() {
    for t in [report::table1(), report::fig2(), report::table2(), report::fig4()] {
        let rendered = t.render();
        assert!(rendered.lines().count() >= 3, "{rendered}");
        let csv = t.to_csv();
        let cols = csv.lines().next().unwrap().split(',').count();
        for line in csv.lines() {
            assert_eq!(line.split(',').count(), cols);
        }
    }
}

#[test]
fn table4_reproduces_packing_gains() {
    let t = report::table4(25);
    let csv = t.to_csv();
    // CNV-W1A1 baseline row and P4 row: efficiency must increase
    let eff = |name: &str| -> f64 {
        csv.lines()
            .find(|l| l.starts_with(name) && !l.starts_with(&format!("{name}-")))
            .unwrap()
            .split(',')
            .nth(3)
            .unwrap()
            .parse()
            .unwrap()
    };
    assert!(eff("CNV-W1A1-P4") > eff("CNV-W1A1"));
    assert!(eff("RN50-W1A2-U250-P4") > eff("RN50-W1A2-U250"));
    // paper: P4 denser than P3
    assert!(eff("CNV-W1A1-P4") >= eff("CNV-W1A1-P3"));
}

#[test]
fn table5_dfps_ordering_matches_paper() {
    let t = report::table5(25);
    let csv = t.to_csv();
    let dfps = |name: &str| -> f64 {
        csv.lines()
            .find(|l| l.starts_with(name))
            .unwrap()
            .split(',')
            .nth(5)
            .unwrap()
            .parse()
            .unwrap()
    };
    // the paper's ordering: CNV 0 <= U250-P4 (~12) <= U280-P4 (~32) < F2 (~51)
    assert!(dfps("CNV-W1A1-7020-P4") <= 1.0);
    assert!(dfps("CNV-W1A1-7012S-P4") <= 1.0);
    let u250 = dfps("RN50-W1A2-U250-P4");
    let u280 = dfps("RN50-W1A2-U280-P4");
    let f2 = dfps("RN50-W1A2-U280-F2");
    assert!(u250 < u280, "{u250} < {u280}");
    assert!(u280 < f2, "{u280} < {f2}");
    assert!((5.0..20.0).contains(&u250));
    assert!((25.0..40.0).contains(&u280));
    assert!((45.0..60.0).contains(&f2));
}

#[test]
fn bypass_fifo_integration_with_rn50_blocks() {
    // size the bypass FIFO from the analytic rule for a real resblock and
    // verify the join sim reaches full rate
    let net = resnet50(1);
    let block = net
        .stages
        .iter()
        .find_map(|s| match s {
            fcmp::nn::Stage::ResBlock { branch, .. } => Some(branch.clone()),
            _ => None,
        })
        .unwrap();
    let cycles: Vec<u64> = block.iter().map(|l| l.cycles_per_frame() / 1000).collect();
    let ii = *cycles.iter().max().unwrap();
    let depth = fcmp::sim::bypass_fifo_pixels(&cycles, 0, ii) as usize;
    let th = fcmp::sim::simulate_resblock_join(&cycles, depth + 1, ii, 60);
    assert!(th > 0.9, "resblock join throughput {th}");
}

#[test]
fn config_drives_packing() {
    // experiment configs parse and select engine parameters
    let cfg = fcmp::config::Config::parse(
        "[packing]\nbin_height = 3\npopulation = 50\np_mut = 0.3\ngenerations = 20\n",
    )
    .unwrap();
    let params = ga::GaParams {
        population: cfg.int_or("packing.population", 75) as usize,
        p_mut: cfg.float_or("packing.p_mut", 0.4),
        generations: cfg.int_or("packing.generations", 120) as usize,
        ..ga::GaParams::cnv()
    };
    let net = cnv(CnvVariant::W1A1);
    let bufs = memory::weight_buffers(&net, 1);
    let items = memory::all_columns(&bufs);
    let c = Constraints::new(cfg.int_or("packing.bin_height", 4) as usize, false);
    let (p, _) = run_packer(&ga::Ga::new(params), &items, &c);
    assert!(p.max_height() <= 3);
}
