//! Property-based invariant tests (via util::prop, our offline proptest
//! substitute) across the packing engines, the GALS streamer, the BRAM
//! mapper and the folding calculus.

use fcmp::device::bram::{brams_for, BRAM18_BITS};
use fcmp::gals::{Ratio, StreamerConfig, StreamerSim};
use fcmp::memory::PackItem;
use fcmp::packing::{anneal::Anneal, ffd::Ffd, ga, run_packer, Constraints, Packer, Packing};
use fcmp::util::prop::{check, Shrink};
use fcmp::util::rng::Rng;

#[derive(Clone, Debug)]
struct ItemSet(Vec<(u64, u64)>); // (width, depth)

impl Shrink for ItemSet {
    fn shrink(&self) -> Vec<ItemSet> {
        let v = &self.0;
        let mut out = Vec::new();
        if v.len() > 1 {
            out.push(ItemSet(v[..v.len() / 2].to_vec()));
            out.push(ItemSet(v[v.len() / 2..].to_vec()));
        }
        out
    }
}

fn to_items(set: &ItemSet) -> Vec<PackItem> {
    set.0
        .iter()
        .enumerate()
        .map(|(i, &(w, d))| PackItem {
            id: i,
            layer: format!("l{i}"),
            width_bits: w,
            depth: d,
            slr: i % 2,
            tenant: 0,
        })
        .collect()
}

fn gen_items(rng: &mut Rng) -> ItemSet {
    let n = 1 + rng.below(24) as usize;
    ItemSet(
        (0..n)
            .map(|_| {
                let w = [4u64, 9, 18, 32, 36][rng.range(0, 5)];
                let d = 8 + rng.below(1200);
                (w, d)
            })
            .collect(),
    )
}

/// Every engine on every input: valid packing, never worse than singletons,
/// capacity lower bound respected.
#[test]
fn prop_engines_sound_and_bounded() {
    check(42, 25, gen_items, |set| {
        let items = to_items(set);
        let engines: Vec<(&str, Box<dyn Packer>)> = vec![
            ("ffd", Box::new(Ffd::new())),
            ("anneal", Box::new(Anneal { iterations: 3000, ..Anneal::default() })),
            (
                "ga",
                Box::new(ga::Ga::new(ga::GaParams {
                    generations: 15,
                    population: 20,
                    ..ga::GaParams::cnv()
                })),
            ),
        ];
        for hb in [2usize, 3, 4] {
            for same_slr in [false, true] {
                let c = Constraints::new(hb, same_slr);
                let single = Packing::singletons(items.len()).total_brams(&items);
                let lb = fcmp::util::ceil_div(
                    items.iter().map(|i| i.bits()).sum::<u64>(),
                    BRAM18_BITS,
                );
                for (name, e) in &engines {
                    let (p, r) = run_packer(e.as_ref(), &items, &c);
                    if let Err(err) = p.validate(&items, &c) {
                        return Err(format!("{name} hb={hb} slr={same_slr}: {err}"));
                    }
                    if r.brams > single {
                        return Err(format!(
                            "{name} hb={hb}: {} > singletons {single}",
                            r.brams
                        ));
                    }
                    if r.brams < lb {
                        return Err(format!(
                            "{name} hb={hb}: {} below capacity bound {lb}",
                            r.brams
                        ));
                    }
                }
            }
        }
        Ok(())
    });
}

/// Island-model GA determinism contract: for a fixed `(seed, islands)` the
/// packing is byte-identical across repeated runs AND across worker-thread
/// counts, and always structurally valid, under H_B ∈ {2,3,4} with and
/// without SLR locality.
#[test]
fn prop_island_ga_deterministic_and_valid() {
    check(17, 8, gen_items, |set| {
        let items = to_items(set);
        for hb in [2usize, 3, 4] {
            for same_slr in [false, true] {
                let c = Constraints::new(hb, same_slr);
                let params = ga::GaParams {
                    generations: 12,
                    population: 24,
                    migration_interval: 4,
                    ..ga::GaParams::cnv()
                }
                .with_islands(3);
                let a = ga::Ga::new(params).with_threads(1).pack(&items, &c);
                let b = ga::Ga::new(params).with_threads(2).pack(&items, &c);
                let b2 = ga::Ga::new(params).with_threads(2).pack(&items, &c);
                if a != b {
                    return Err(format!(
                        "hb={hb} slr={same_slr}: 1-thread and 2-thread packings differ"
                    ));
                }
                if b != b2 {
                    return Err(format!(
                        "hb={hb} slr={same_slr}: repeated 2-thread runs differ"
                    ));
                }
                if let Err(e) = a.validate(&items, &c) {
                    return Err(format!("hb={hb} slr={same_slr}: invalid: {e}"));
                }
            }
        }
        Ok(())
    });
}

/// Larger H_B never hurts the GA solution (more freedom).
#[test]
fn prop_bin_height_monotone() {
    check(7, 15, gen_items, |set| {
        let items = to_items(set);
        let pack = |hb: usize| {
            let e = ga::Ga::new(ga::GaParams {
                generations: 20,
                population: 24,
                ..ga::GaParams::cnv()
            });
            run_packer(&e, &items, &Constraints::new(hb, false)).1.brams
        };
        let (h2, h4) = (pack(2), pack(4));
        if h4 > h2 {
            return Err(format!("H_B=4 ({h4}) worse than H_B=2 ({h2})"));
        }
        Ok(())
    });
}

/// brams_for respects the information-capacity lower bound and is monotone
/// in both width and depth.
///
/// NOTE a tempting stronger property — "splitting a buffer in depth never
/// reduces the total BRAM count" — is FALSE on the real aspect-mode
/// lattice: e.g. 19x2058 costs 5 BRAMs (36x512 mode), but 19x142 + 19x1916
/// costs 1 + 3 (the tail fits the 9x2048 mode three columns wide) = 4.
/// The depth-stacking packer exploits exactly this kind of regrouping.
#[test]
fn prop_bram_mapper_bounds_and_monotonicity() {
    check(9, 300, |r| {
        let w = 1 + r.below(40);
        let d = 2 + r.below(4000);
        let cut = 1 + r.below(d - 1);
        vec![w, d, cut]
    }, |v| {
        if v.len() < 3 {
            return Ok(()); // shrunk vectors degenerate harmlessly
        }
        let (w, d, dw) = (v[0], v[1], v[2]);
        let n = brams_for(w, d);
        // capacity bound: a BRAM18 stores at most 18 Kib
        let lb = fcmp::util::ceil_div(w * d, BRAM18_BITS);
        if n < lb {
            return Err(format!("{w}x{d}: {n} below capacity bound {lb}"));
        }
        // monotone in both dimensions
        if brams_for(w + 1, d) < n || brams_for(w, d + dw.max(1)) < n {
            return Err(format!("{w}x{d}: not monotone"));
        }
        Ok(())
    });
}

/// GALS: min rate equals min(1, 2*R_F / N_b) for even N_b (Fig. 7a law),
/// for arbitrary depths and FIFO sizes.
#[test]
fn prop_streamer_rate_law() {
    check(13, 20, |r| {
        let nb = 2 * (1 + r.below(4)) as usize; // 2,4,6,8
        let rf = 1 + r.below(3); // 1..3
        let depth = 8 + r.below(500);
        let fifo = 2 + r.below(14) as usize;
        vec![nb as u64, rf, depth, fifo as u64]
    }, |v| {
        if v.len() < 4 || v[0] < 2 || v[1] == 0 || v[2] == 0 || v[3] == 0 {
            return Ok(());
        }
        let (nb, rf, depth, fifo) = (v[0] as usize, v[1], v[2], v[3] as usize);
        let mut cfg = StreamerConfig::fig7a(nb, depth, Ratio::new(rf, 1));
        cfg.fifo_depth = fifo;
        let r = StreamerSim::new(cfg).run(3_000);
        let expect = (2.0 * rf as f64 / nb as f64).min(1.0);
        let got = r.min_rate();
        if (got - expect).abs() > 0.05 * expect.max(0.1) {
            return Err(format!("nb={nb} rf={rf}: rate {got} != {expect}"));
        }
        Ok(())
    });
}

/// Folding: fold_to_target always meets the target when feasible, and the
/// buffer bits are invariant under any folding.
#[test]
fn prop_fold_to_target() {
    check(21, 60, |r| {
        let c_in = 1 + r.below(256);
        let c_out = [16u64, 32, 64, 128, 256][r.range(0, 5)];
        let k = [1u64, 3][r.range(0, 2)];
        let ifm = 4 + r.below(60);
        let target = 1_000 + r.below(2_000_000);
        vec![c_in, c_out, k, ifm, target]
    }, |v| {
        if v.len() < 5 || v[..4].iter().any(|&x| x == 0) {
            return Ok(());
        }
        let (c_in, c_out, k, ifm, target) = (v[0], v[1], v[2], v[3], v[4]);
        let mut l = fcmp::nn::Layer {
            name: "p".into(),
            kind: fcmp::nn::LayerKind::Conv,
            k,
            c_in,
            c_out,
            stride: 1,
            pad: 0,
            ifm: ifm + k, // ensure ofm >= 1
            wbits: 1,
            abits: 2,
            pe: 1,
            simd: 1,
            exclude_from_packing: false,
        };
        let bits_before = l.weight_bits();
        l.fold_to_target(target);
        if !l.folding_valid() {
            return Err(format!("invalid folding pe={} simd={}", l.pe, l.simd));
        }
        if l.buffer_width_bits() * l.buffer_depth() != bits_before {
            return Err("folding changed total bits".into());
        }
        // feasibility: the fully parallel fold is the floor
        let min_cycles = l.ofm() * l.ofm();
        if min_cycles <= target && l.cycles_per_frame() > target {
            return Err(format!(
                "target {target} feasible (floor {min_cycles}) but got {}",
                l.cycles_per_frame()
            ));
        }
        Ok(())
    });
}

/// Timing: delta-FPS is monotone in LUT utilization on multi-die parts.
#[test]
fn prop_timing_monotone_in_density() {
    check(31, 200, |r| vec![r.below(1000), r.below(1000)], |v| {
        if v.len() < 2 {
            return Ok(());
        }
        let (a, b) = (v[0] as f64 / 1000.0, v[1] as f64 / 1000.0);
        let (lo, hi) = if a <= b { (a, b) } else { (b, a) };
        let dev = fcmp::device::alveo_u250();
        let ta = fcmp::timing::evaluate(&dev, lo, 200.0, 2.0, 200.0);
        let tb = fcmp::timing::evaluate(&dev, hi, 200.0, 2.0, 200.0);
        if tb.effective_fc_mhz > ta.effective_fc_mhz + 1e-9 {
            return Err(format!(
                "effective clock rose with density: {lo}->{} {hi}->{}",
                ta.effective_fc_mhz, tb.effective_fc_mhz
            ));
        }
        Ok(())
    });
}
