"""L2 model-level tests: topology shapes, streamline structure, determinism,
and the resblock branch/join semantics."""

from __future__ import annotations

import numpy as np
import pytest
import jax.numpy as jnp

from compile import model as M
from compile.kernels.ref import mvau_ref


# ---------------------------------------------------------------- topology


def test_cnv_weight_totals():
    """CNV parameter count matches the published BNN-Pynq topology
    (~1.54M weights plus the FINN 16-wide padded final layer)."""
    layers = M.cnv_layers(1, 1)
    total = sum(l.synapses * l.c_out for l in layers)
    # 1,542,848 with a 10-wide final layer; ours is padded to 16 outputs.
    assert total == 1_542_848 - 512 * 10 + 512 * 16


def test_cnv_folding_divides():
    for l in M.cnv_layers(1, 1):
        assert l.c_out % l.pe == 0, l.name
        assert l.synapses % l.simd == 0, l.name


def test_resnet50_block_structure():
    blocks = M.resnet50_blocks()
    assert len(blocks) == 16
    assert sum(1 for b in blocks if b.downsample) == 4
    # channel doubling sequence 256 -> 512 -> 1024 -> 2048
    outs = sorted({b.c_out for b in blocks})
    assert outs == [256, 512, 1024, 2048]
    # stage layout 3/4/6/3
    assert [b.c_mid for b in blocks].count(64) == 3
    assert [b.c_mid for b in blocks].count(128) == 4
    assert [b.c_mid for b in blocks].count(256) == 6
    assert [b.c_mid for b in blocks].count(512) == 3


def test_resnet50_conv_counts():
    """16 resblocks, 4-conv type A x4 + 3-conv type B x12 = 52 resblock convs
    (the paper's section III description)."""
    layers = M.rn50_layers = [
        l
        for b in M.resnet50_blocks()
        for l in M.resblock_layers(b, 1, 4, 8)
    ]
    assert len(layers) == 4 * 4 + 12 * 3
    k3 = [l for l in layers if l.kernel == 3]
    assert len(k3) == 16  # exactly one 3x3 per resblock


def test_resnet50_param_count_full():
    """Full-size quantized RN50 resblock weights ~= 23.5M (the OCM budget the
    paper packs; top/bottom 8-bit layers excluded)."""
    layers = [
        l for b in M.resnet50_blocks() for l in M.resblock_layers(b, 1, 4, 8)
    ]
    total = sum(l.synapses * l.c_out for l in layers)
    assert 20e6 < total < 27e6


def test_width_scale_shrinks():
    full = M.resnet50_blocks(1.0)
    lite = M.resnet50_blocks(0.25)
    assert all(l.c_out == f.c_out // 4 for f, l in zip(full, lite))


# ---------------------------------------------------------------- im2col


@pytest.mark.parametrize("k,stride,pad", [(3, 1, 0), (3, 1, 1), (3, 2, 1), (7, 2, 3), (1, 1, 0)])
def test_im2col_matches_conv(k, stride, pad):
    """im2col + matmul == lax.conv (the FINN sliding-window decomposition)."""
    rng = np.random.RandomState(9)
    n, h, c_in, c_out = 2, 8, 3, 5
    x = jnp.array(rng.randn(n, h, h, c_in).astype(np.float32))
    w = jnp.array(rng.randn(k, k, c_in, c_out).astype(np.float32))
    import jax as _jax

    cols = M.im2col(x, k, stride, pad)
    wmat = w.reshape(k * k * c_in, c_out)
    got = cols @ wmat
    want = _jax.lax.conv_general_dilated(
        x, w, (stride, stride), [(pad, pad), (pad, pad)],
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    ho = M.out_dim(h, k, stride, pad)
    np.testing.assert_allclose(
        np.asarray(got).reshape(n, ho, ho, c_out), np.asarray(want),
        rtol=1e-4, atol=1e-4,
    )


# ---------------------------------------------------------------- forward


def _cnv_small_forward(wbits, abits):
    layers = M.cnv_layers(wbits, abits)
    params = [jnp.array(p) for p in M.init_params(layers, seed=5)]
    x = jnp.array(
        np.random.RandomState(0).randint(0, 256, (1, 32, 32, 3)).astype(np.float32)
    )
    return M.cnv_forward(x, params, wbits, abits)


@pytest.mark.slow
def test_cnv_w1a1_forward_shape_and_determinism():
    y1 = np.asarray(_cnv_small_forward(1, 1))
    y2 = np.asarray(_cnv_small_forward(1, 1))
    assert y1.shape == (1, 16)
    np.testing.assert_array_equal(y1, y2)
    assert np.all(y1 == np.round(y1))  # integer-valued accumulators


@pytest.mark.slow
def test_rn50_lite_forward():
    layers = M.rn50_param_layers(1, 0.25)
    params = [jnp.array(p) for p in M.init_params(layers, interleaved=True)]
    x = jnp.array(
        np.random.RandomState(1).randint(0, 256, (1, 32, 32, 3)).astype(np.float32)
    )
    y = np.asarray(M.rn50_forward(x, params, 1, 0.25))
    assert y.shape == (1, 16)
    assert np.all(np.isfinite(y))


def test_requant_levels():
    x = jnp.array([-9.0, -3.0, -0.4, 0.6, 2.0, 9.0])
    out = np.asarray(M._requant(x, 2))
    assert set(np.unique(out)).issubset({-2.0, -1.0, 0.0, 1.0})
    assert out[0] == -2.0 and out[-1] == 1.0


def test_init_layer_deterministic_and_quantized():
    layer = M.MvauLayer("t", 3, 8, 16, wbits=2, abits=2, pe=1, simd=1)
    w1, t1 = M.init_layer(layer, 42)
    w2, t2 = M.init_layer(layer, 42)
    np.testing.assert_array_equal(w1, w2)
    np.testing.assert_array_equal(t1, t2)
    assert set(np.unique(w1)).issubset({-1.0, 0.0, 1.0})
    assert np.all(np.diff(t1, axis=1) >= 0)  # ascending thresholds


def test_init_params_order():
    layers = M.cnv_layers(1, 1)
    flat = M.init_params(layers)
    inter = M.init_params(layers, interleaved=True)
    assert len(flat) == len(inter) == 2 * len(layers)
    np.testing.assert_array_equal(flat[0], inter[0])  # w0
    np.testing.assert_array_equal(flat[len(layers)], inter[1])  # t0
