"""Pallas MVAU kernel vs the pure-jnp oracle -- the core L1 correctness
signal.  hypothesis sweeps shapes, foldings, weight/activation precisions and
pixel tiling; all outputs are integer-valued f32 so comparisons are exact."""

from __future__ import annotations

import numpy as np
import pytest
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels.mvau import mvau, mvau_vmem_bits, _pick_tile
from compile.kernels.ref import mvau_ref, threshold_params


def _divisors(n: int) -> list[int]:
    return [d for d in range(1, n + 1) if n % d == 0]


def make_case(rng, p, s, c, wbits, abits):
    if wbits == 1:
        w = rng.choice([-1.0, 1.0], (s, c))
    else:
        w = rng.choice([-1.0, 0.0, 1.0], (s, c))
    x = rng.randint(-3, 4, (p, s)).astype(np.float64)
    if abits == 0:
        t = np.zeros((c, 0))
        base, step = 0.0, 1.0
    else:
        nt, base, step = threshold_params(abits, signed=abits != 1)
        t = np.sort(np.round(rng.uniform(-s, s, (c, nt))), axis=1)
    return (
        jnp.array(x, jnp.float32),
        jnp.array(w, jnp.float32),
        jnp.array(t, jnp.float32),
        base,
        step,
    )


@st.composite
def mvau_cases(draw):
    p = draw(st.integers(1, 48))
    s_factor = draw(st.sampled_from([1, 2, 3, 4, 6, 9]))
    c = draw(st.sampled_from([2, 4, 8, 16, 24]))
    s = s_factor * draw(st.sampled_from([2, 4, 8]))
    pe = draw(st.sampled_from(_divisors(c)))
    simd = draw(st.sampled_from(_divisors(s)))
    wbits = draw(st.sampled_from([1, 2]))
    abits = draw(st.sampled_from([0, 1, 2, 4]))
    seed = draw(st.integers(0, 2**31 - 1))
    tile = draw(st.sampled_from([1, 8, 32, 64]))
    return p, s, c, pe, simd, wbits, abits, seed, tile


@settings(max_examples=60, deadline=None)
@given(mvau_cases())
def test_mvau_matches_ref_hypothesis(case):
    p, s, c, pe, simd, wbits, abits, seed, tile = case
    rng = np.random.RandomState(seed)
    x, w, t, base, step = make_case(rng, p, s, c, wbits, abits)
    out = mvau(x, w, t, pe=pe, simd=simd, base=base, step=step, pixel_tile=tile)
    ref = mvau_ref(x, w, t, base=base, step=step)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


@pytest.mark.parametrize("pe,simd", [(1, 1), (4, 8), (16, 3), (64, 72)])
def test_mvau_folding_invariance(pe, simd):
    """Folding (PE, SIMD) is a schedule, not a semantics: all foldings give
    identical results."""
    rng = np.random.RandomState(11)
    x, w, t, base, step = make_case(rng, 20, 72, 64, 1, 2)
    ref = mvau_ref(x, w, t, base=base, step=step)
    out = mvau(x, w, t, pe=pe, simd=simd, base=base, step=step)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_mvau_bypass_is_exact_matmul():
    rng = np.random.RandomState(3)
    x, w, t, _, _ = make_case(rng, 16, 32, 8, 1, 0)
    out = mvau(x, w, t, pe=2, simd=4)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(x) @ np.asarray(w))


def test_threshold_count_semantics():
    """out = base + step * #crossed, checked against a hand computation."""
    x = jnp.array([[2.0, -1.0]])  # acc = 2*1 + (-1)*1 = 1
    w = jnp.array([[1.0], [1.0]])
    t = jnp.array([[-1.0, 0.0, 3.0]])  # crossed: -1, 0 => count 2
    out = mvau(x, w, t, pe=1, simd=1, base=-2.0, step=1.0)
    assert float(out[0, 0]) == -2.0 + 2.0


def test_bipolar_1bit_levels():
    nt, base, step = threshold_params(1)
    assert (nt, base, step) == (1, -1.0, 2.0)
    rng = np.random.RandomState(5)
    x, w, t, _, _ = make_case(rng, 10, 16, 4, 1, 1)
    out = np.asarray(mvau(x, w, t, pe=2, simd=4, base=base, step=step))
    assert set(np.unique(out)).issubset({-1.0, 1.0})


def test_signed_2bit_levels():
    rng = np.random.RandomState(6)
    x, w, t, base, step = make_case(rng, 32, 36, 8, 1, 2)
    out = np.asarray(mvau(x, w, t, pe=4, simd=6, base=base, step=step))
    assert set(np.unique(out)).issubset({-2.0, -1.0, 0.0, 1.0})


def test_signed_4bit_levels():
    rng = np.random.RandomState(7)
    x, w, t, base, step = make_case(rng, 8, 18, 4, 2, 4)
    out = np.asarray(mvau(x, w, t, pe=2, simd=3, base=base, step=step))
    assert out.min() >= -8.0 and out.max() <= 7.0


def test_pick_tile_divides():
    for n in range(1, 200):
        for target in (1, 7, 32, 200):
            t = _pick_tile(n, target)
            assert n % t == 0 and 1 <= t <= min(n, target)


def test_fold_constraints_rejected():
    rng = np.random.RandomState(8)
    x, w, t, base, step = make_case(rng, 4, 12, 8, 1, 2)
    with pytest.raises(AssertionError):
        mvau(x, w, t, pe=3, simd=4, base=base, step=step)  # 3 !| 8
    with pytest.raises(AssertionError):
        mvau(x, w, t, pe=2, simd=5, base=base, step=step)  # 5 !| 12


def test_vmem_estimate_monotone_in_tiles():
    """VMEM footprint (the TPU analogue of the BRAM budget) grows with the
    folding tile sizes -- the knob the perf pass turns."""
    base = mvau_vmem_bits(pe=4, simd=8, bp=32, nt=3, wbits=1)
    assert mvau_vmem_bits(pe=8, simd=8, bp=32, nt=3, wbits=1) > base
    assert mvau_vmem_bits(pe=4, simd=16, bp=32, nt=3, wbits=1) > base
    assert mvau_vmem_bits(pe=4, simd=8, bp=64, nt=3, wbits=1) > base
