"""Pallas maxpool kernel vs jax.lax.reduce_window oracle."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.maxpool import maxpool2x2


def ref_pool(x):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    )


@settings(max_examples=8, deadline=None)
@given(
    st.integers(1, 2),
    st.sampled_from([1, 2, 4]),
    st.sampled_from([1, 2, 4]),
    st.sampled_from([1, 4, 8]),
    st.integers(0, 2**31 - 1),
)
def test_maxpool_matches_reduce_window(n, h2, w2, c, seed):
    rng = np.random.RandomState(seed)
    x = jnp.array(rng.randint(-4, 4, (n, 2 * h2, 2 * w2, c)).astype(np.float32))
    np.testing.assert_array_equal(
        np.asarray(maxpool2x2(x)), np.asarray(ref_pool(x))
    )


def test_maxpool_cnv_shape():
    # the CNV pool stages: 28x28x64 -> 14x14x64
    rng = np.random.RandomState(0)
    x = jnp.array(rng.choice([-1.0, 1.0], (1, 28, 28, 64)).astype(np.float32))
    got = maxpool2x2(x)
    assert got.shape == (1, 14, 14, 64)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref_pool(x)))


def test_maxpool_preserves_quant_levels():
    rng = np.random.RandomState(1)
    x = jnp.array(rng.choice([-2.0, -1.0, 0.0, 1.0], (2, 8, 8, 4)).astype(np.float32))
    out = np.asarray(maxpool2x2(x))
    assert set(np.unique(out)).issubset({-2.0, -1.0, 0.0, 1.0})


def test_maxpool_rejects_odd_dims():
    with pytest.raises(AssertionError):
        maxpool2x2(jnp.zeros((1, 3, 4, 2), jnp.float32))
