"""Layer-2: streamlined quantized CNN graphs in JAX, built on the L1 MVAU.

Two network families, mirroring the paper's evaluation targets:

* **CNV** -- the BNN-Pynq CIFAR-10 topology (6x conv3x3 VALID + 2x maxpool +
  3x FC), in W1A1 and W2A2 variants.  This is the paper's embedded-class
  accelerator (Zynq 7020 / 7012S).
* **ResNet-50** -- 16 residual blocks (1x1 / 3x3 / 1x1 + optional 1x1
  downsample branch), channel doubling at 4 block boundaries, W1A2 / W2A2.
  The *executable* artifact is a channel-scaled "lite" variant (see
  DESIGN.md substitutions: full-size RN50 shapes drive the analytic rust
  experiments; the lite variant proves the three-layer stack end to end).

Every convolution is lowered as im2col (``conv_general_dilated_patches``)
followed by the Pallas MVAU kernel, exactly the FINN decomposition of a
convolution into a sliding-window generator + matrix-vector unit.  Batch norm
and quantized activations are already folded into MVAU thresholds
("streamlining"), so the graph contains only MVAUs, maxpools, the residual
add/re-quantize, and the final pooling/classifier.

All model functions take the input image batch plus every weight/threshold
tensor as *arguments* (no giant HLO constants): the rust runtime feeds the
``.bin`` weight files emitted by ``aot.py``.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .kernels.mvau import mvau
from .kernels.ref import threshold_params


# --------------------------------------------------------------------------
# Layer descriptors
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MvauLayer:
    """One streamlined MVAU layer (conv or FC) with its folding."""

    name: str
    kernel: int  # K (1 for FC / pointwise)
    c_in: int
    c_out: int
    stride: int = 1
    pad: int = 0
    wbits: int = 1  # 1 = binary {-1,+1}, 2 = ternary {-1,0,+1}, 8 = int8
    abits: int = 1  # output activation bits; 0 = bypass (raw accumulator)
    signed: bool = True  # signed output levels (False => bipolar {-1,+1})
    pe: int = 1
    simd: int = 1

    @property
    def synapses(self) -> int:
        return self.kernel * self.kernel * self.c_in

    @property
    def weight_shape(self) -> tuple[int, int]:
        return (self.synapses, self.c_out)

    @property
    def num_thresholds(self) -> int:
        if self.abits == 0:
            return 0
        return threshold_params(self.abits, self.signed)[0]

    def level_map(self) -> tuple[float, float]:
        if self.abits == 0:
            return 0.0, 1.0
        _, base, step = threshold_params(self.abits, self.signed)
        return base, step


def im2col(x: jax.Array, k: int, stride: int, pad: int) -> jax.Array:
    """NHWC image -> (N*H'*W', K*K*C) im2col matrix (FINN sliding window).

    Feature ordering is (ky, kx, c) to match the weight layout produced by
    :func:`init_layer`.
    """
    n, h, w, c = x.shape
    if k == 1 and stride == 1 and pad == 0:
        return x.reshape(n * h * w, c)
    patches = jax.lax.conv_general_dilated_patches(
        x,
        filter_shape=(k, k),
        window_strides=(stride, stride),
        padding=[(pad, pad), (pad, pad)],
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    # patches feature dim is ordered (c, ky, kx); reorder to (ky, kx, c).
    nh, nw = patches.shape[1], patches.shape[2]
    patches = patches.reshape(n, nh, nw, c, k, k)
    patches = jnp.transpose(patches, (0, 1, 2, 4, 5, 3))
    return patches.reshape(n * nh * nw, k * k * c)


def out_dim(h: int, k: int, stride: int, pad: int) -> int:
    return (h + 2 * pad - k) // stride + 1


def apply_mvau(
    x: jax.Array, layer: MvauLayer, w: jax.Array, t: jax.Array, h: int, wdim: int
) -> tuple[jax.Array, int, int]:
    """Run one MVAU layer on an NHWC tensor; returns (NHWC out, H', W')."""
    n = x.shape[0]
    cols = im2col(x, layer.kernel, layer.stride, layer.pad)
    base, step = layer.level_map()
    y = mvau(cols, w, t, pe=layer.pe, simd=layer.simd, base=base, step=step)
    ho = out_dim(h, layer.kernel, layer.stride, layer.pad)
    wo = out_dim(wdim, layer.kernel, layer.stride, layer.pad)
    return y.reshape(n, ho, wo, layer.c_out), ho, wo


def maxpool2(x: jax.Array) -> jax.Array:
    """2x2 stride-2 max pool (quantized levels are order-preserving)."""
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    )


# --------------------------------------------------------------------------
# CNV (BNN-Pynq) topology
# --------------------------------------------------------------------------


def cnv_layers(wbits: int, abits: int) -> list[MvauLayer]:
    """The BNN-Pynq CNV topology: 32x32x3 CIFAR-10 input, 6 conv3x3 VALID,
    maxpool after conv pairs 2 and 4, three FC layers, 10-class output.

    First layer consumes 8-bit input images (weights still quantized); the
    final FC emits raw accumulators (no activation), as in FINN.  PE/SIMD
    folding follows the max-performance BNN-Pynq configuration.  FINN pads
    the final FC to 16 outputs for folding; the first 10 are the classes.
    """
    aspec = dict(abits=abits, signed=abits != 1)
    return [
        MvauLayer("conv1", 3, 3, 64, wbits=wbits, pe=16, simd=3, **aspec),
        MvauLayer("conv2", 3, 64, 64, wbits=wbits, pe=32, simd=32, **aspec),
        MvauLayer("conv3", 3, 64, 128, wbits=wbits, pe=16, simd=32, **aspec),
        MvauLayer("conv4", 3, 128, 128, wbits=wbits, pe=16, simd=32, **aspec),
        MvauLayer("conv5", 3, 128, 256, wbits=wbits, pe=4, simd=32, **aspec),
        MvauLayer("conv6", 3, 256, 256, wbits=wbits, pe=1, simd=32, **aspec),
        MvauLayer("fc1", 1, 256, 512, wbits=wbits, pe=1, simd=4, **aspec),
        MvauLayer("fc2", 1, 512, 512, wbits=wbits, pe=1, simd=8, **aspec),
        MvauLayer("fc3", 1, 512, 16, wbits=wbits, abits=0, pe=4, simd=1),
    ]


def exec_fold(layer: MvauLayer) -> MvauLayer:
    """Execution folding for the AOT artifact: full PE/SIMD so the Pallas
    grid collapses to one step per pixel tile. The FINN folding (the paper's
    PE/SIMD) is a *schedule*, proven equivalent by the kernel tests; the
    interpret-mode executable uses the largest tiles for CPU speed while the
    rust analytic/sim layers keep the true folded schedule."""
    return dataclasses.replace(layer, pe=layer.c_out, simd=layer.synapses)


def cnv_forward(x: jax.Array, params: Sequence[jax.Array], wbits: int, abits: int,
                full_fold: bool = False):
    """CNV inference: x (N,32,32,3) -> logits (N,16)."""
    layers = cnv_layers(wbits, abits)
    if full_fold:
        layers = [exec_fold(l) for l in layers]
    ws, ts = params[: len(layers)], params[len(layers) :]
    h = wdim = 32
    pool_after = {"conv2", "conv4"}
    x_cur = x
    for i, layer in enumerate(layers[:6]):
        x_cur, h, wdim = apply_mvau(x_cur, layer, ws[i], ts[i], h, wdim)
        if layer.name in pool_after:
            x_cur = maxpool2(x_cur)
            h //= 2
            wdim //= 2
    # conv6 output is 1x1x256 -> flatten through the FC stack
    n = x_cur.shape[0]
    x_cur = x_cur.reshape(n, 1, 1, -1)
    h = wdim = 1
    for i, layer in enumerate(layers[6:], start=6):
        x_cur, h, wdim = apply_mvau(x_cur, layer, ws[i], ts[i], h, wdim)
    return x_cur.reshape(n, -1)


# --------------------------------------------------------------------------
# ResNet-50 topology
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ResBlockSpec:
    """One residual block: conv1x1 (reduce) -> conv3x3 -> conv1x1 (expand),
    plus an optional 1x1 downsample on the bypass branch (paper Fig. 3)."""

    name: str
    c_in: int
    c_mid: int
    c_out: int
    stride: int = 1
    downsample: bool = False  # 4-conv "type A" block vs 3-conv "type B"


def resnet50_blocks(width_scale: float = 1.0) -> list[ResBlockSpec]:
    """The 16 ResBlocks of ResNet-50 v1.5 (stage layout 3/4/6/3); stride-2 in
    the 3x3 conv of each stage's first block (v1.5 convention)."""
    blocks: list[ResBlockSpec] = []
    c_in = int(64 * width_scale)
    stage_mid = [int(64 * width_scale), int(128 * width_scale),
                 int(256 * width_scale), int(512 * width_scale)]
    stage_n = [3, 4, 6, 3]
    for s, (mid, n) in enumerate(zip(stage_mid, stage_n)):
        c_out = mid * 4
        for b in range(n):
            first = b == 0
            blocks.append(
                ResBlockSpec(
                    name=f"res{s + 2}{'abcdef'[b]}",
                    c_in=c_in,
                    c_mid=mid,
                    c_out=c_out,
                    stride=2 if (first and s > 0) else 1,
                    downsample=first,
                )
            )
            c_in = c_out
    return blocks


def resblock_layers(blk: ResBlockSpec, wbits: int, pe: int, simd: int) -> list[MvauLayer]:
    """MVAU layers of one resblock.  Activations into the elementwise add are
    4-bit signed; all others 2-bit signed (paper section III.A)."""
    layers = [
        MvauLayer(f"{blk.name}_c1", 1, blk.c_in, blk.c_mid, wbits=wbits, abits=2,
                  pe=pe, simd=simd),
        MvauLayer(f"{blk.name}_c2", 3, blk.c_mid, blk.c_mid, stride=blk.stride,
                  pad=1, wbits=wbits, abits=2, pe=pe, simd=simd),
        MvauLayer(f"{blk.name}_c3", 1, blk.c_mid, blk.c_out, wbits=wbits, abits=4,
                  pe=pe, simd=simd),
    ]
    if blk.downsample:
        layers.append(
            MvauLayer(f"{blk.name}_cb", 1, blk.c_in, blk.c_out, stride=blk.stride,
                      wbits=wbits, abits=4, pe=pe, simd=simd)
        )
    return layers


def _requant(x: jax.Array, abits: int) -> jax.Array:
    """Re-quantize the residual sum to ``abits`` signed levels (the
    stand-alone thresholding unit after the elementwise add)."""
    lo = -(1 << (abits - 1))
    hi = (1 << (abits - 1)) - 1
    return jnp.clip(jnp.round(x / 2.0), lo, hi)


def rn50_param_layers(wbits: int, width_scale: float, pe: int = 4, simd: int = 8):
    """Parameter layer list in the exact order consumed by rn50_forward."""
    blocks = resnet50_blocks(width_scale)
    c0 = blocks[0].c_in
    out: list[MvauLayer] = [
        MvauLayer("conv_top", 7, 3, c0, stride=2, pad=3, wbits=8, abits=4,
                  pe=max(1, c0 // 8), simd=3)
    ]
    for blk in blocks:
        out.extend(resblock_layers(blk, wbits, pe, simd))
    out.append(
        MvauLayer("fc_out", 1, blocks[-1].c_out, 16, wbits=8, abits=0, pe=1, simd=1)
    )
    return out


def rn50_forward(
    x: jax.Array,
    params: Sequence[jax.Array],
    wbits: int,
    width_scale: float,
    pe: int = 4,
    simd: int = 8,
    full_fold: bool = False,
):
    """Quantized ResNet-50 inference (lite variant executes end to end).

    x: (N, image, image, 3).  params interleaved [w0, t0, w1, t1, ...] in
    :func:`rn50_param_layers` order.  Top (7x7 conv + maxpool) and bottom
    (avgpool + FC) layers use 8-bit weights per the paper and are excluded
    from memory packing on the rust side.
    """
    blocks = resnet50_blocks(width_scale)
    c0 = blocks[0].c_in
    top = MvauLayer("conv_top", 7, 3, c0, stride=2, pad=3, wbits=8, abits=4,
                    pe=max(1, c0 // 8), simd=3)
    if full_fold:
        top = exec_fold(top)
    it = iter(params)

    def nxt():
        return next(it)

    n, image = x.shape[0], x.shape[1]
    h = wdim = image
    x, h, wdim = apply_mvau(x, top, nxt(), nxt(), h, wdim)
    x = jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 3, 3, 1), (1, 2, 2, 1), "SAME"
    )
    h = (h + 1) // 2
    wdim = (wdim + 1) // 2

    for blk in blocks:
        layers = resblock_layers(blk, wbits, pe, simd)
        if full_fold:
            layers = [exec_fold(l) for l in layers]
        bypass = x
        bh, bw = h, wdim
        x, h, wdim = apply_mvau(x, layers[0], nxt(), nxt(), h, wdim)
        x, h, wdim = apply_mvau(x, layers[1], nxt(), nxt(), h, wdim)
        x, h, wdim = apply_mvau(x, layers[2], nxt(), nxt(), h, wdim)
        if blk.downsample:
            bypass, _, _ = apply_mvau(bypass, layers[3], nxt(), nxt(), bh, bw)
        x = _requant(x + bypass, 2)

    x = jnp.mean(x, axis=(1, 2))  # global average pool
    fc_w, fc_t = nxt(), nxt()
    y = mvau(x, fc_w, fc_t, pe=1, simd=1)
    return y.reshape(n, -1)


# --------------------------------------------------------------------------
# Deterministic synthetic weights (DESIGN.md substitution: shapes exact,
# values synthetic; golden I/O pins rust <-> python numerics)
# --------------------------------------------------------------------------


def init_layer(layer: MvauLayer, seed: int) -> tuple[np.ndarray, np.ndarray]:
    """Deterministic quantized weights + ascending thresholds for one layer."""
    rng = np.random.RandomState(seed % (2**31 - 1))
    s, c = layer.weight_shape
    if layer.wbits == 1:
        w = rng.choice([-1.0, 1.0], size=(s, c))
    elif layer.wbits == 2:
        w = rng.choice([-1.0, 0.0, 1.0], size=(s, c))
    else:  # int8-ish top/bottom layers
        w = rng.randint(-8, 9, size=(s, c)).astype(np.float64)
    nt = layer.num_thresholds
    # center thresholds on 0 with spread ~ sqrt(fan-in) so output levels vary
    spread = max(2.0, np.sqrt(s))
    t = np.sort(rng.uniform(-spread, spread, size=(c, nt)), axis=1)
    t = np.round(t)  # integer thresholds, exact in f32
    return w.astype(np.float32), t.astype(np.float32)


def init_params(layers: Sequence[MvauLayer], seed: int = 2020, interleaved: bool = False):
    """Weights/thresholds for a layer list.

    interleaved=True yields [w0, t0, w1, t1, ...] (rn50_forward order);
    False yields [w0..wn, t0..tn] (cnv_forward order).
    """
    ws, ts = [], []
    for i, layer in enumerate(layers):
        w, t = init_layer(layer, seed + i * 7919)
        ws.append(w)
        ts.append(t)
    if interleaved:
        out: list[np.ndarray] = []
        for w, t in zip(ws, ts):
            out.extend((w, t))
        return out
    return ws + ts
