"""Layer-1 Pallas kernel: 2x2/2 max-pool over NHWC quantized activations.

The FINN pipeline interleaves max-pool units between MVAUs; on quantized
levels max is order-preserving so the unit is exact.  Grid: one step per
(batch, row-pair); the BlockSpec stages two input rows and emits one output
row -- the same line-buffer schedule the FPGA sliding-window unit uses.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pool_kernel(x_ref, o_ref):
    x = x_ref[...]  # (1, 2, W, C)
    pairs = x.reshape(1, 2, x.shape[2] // 2, 2, x.shape[3])
    o_ref[...] = jnp.max(jnp.max(pairs, axis=3), axis=1, keepdims=True)


@jax.jit
def maxpool2x2(x: jax.Array) -> jax.Array:
    """(N, H, W, C) -> (N, H//2, W//2, C) max pool; H and W must be even."""
    n, h, w, c = x.shape
    assert h % 2 == 0 and w % 2 == 0, f"even dims required, got {h}x{w}"
    return pl.pallas_call(
        _pool_kernel,
        grid=(n, h // 2),
        in_specs=[pl.BlockSpec((1, 2, w, c), lambda i, j: (i, j, 0, 0))],
        out_specs=pl.BlockSpec((1, 1, w // 2, c), lambda i, j: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, h // 2, w // 2, c), x.dtype),
        interpret=True,
    )(x)
