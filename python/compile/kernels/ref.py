"""Pure-jnp oracle for the MVAU kernel (correctness reference).

No Pallas, no folding: a plain dense matmul plus the uniform-quantization
thresholding map.  ``python/tests/test_kernel.py`` sweeps the Pallas kernel
against this with hypothesis over shapes / foldings / threshold counts.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def mvau_ref(
    x: jax.Array,
    w: jax.Array,
    t: jax.Array,
    *,
    base: float = 0.0,
    step: float = 1.0,
) -> jax.Array:
    """Reference MVAU: ``out = base + step * #{thresholds crossed}``.

    Shapes as in :func:`compile.kernels.mvau.mvau`; ``t`` with 0 columns
    bypasses the activation (raw accumulator out).
    """
    acc = jnp.dot(x, w, preferred_element_type=jnp.float32)
    if t.shape[1] == 0:
        return acc
    crossed = (acc[:, :, None] >= t[None, :, :]).astype(jnp.float32)
    return base + step * jnp.sum(crossed, axis=2)


def threshold_params(abits: int, signed: bool = True) -> tuple[int, float, float]:
    """Return (num_thresholds, base, step) for an ``abits``-bit uniform
    quantizer.

    signed: levels -2^(a-1) .. 2^(a-1)-1 with unit step (paper's 2b/4b
    activations); unsigned-bipolar 1-bit: levels {-1, +1} with step 2
    (BNN-Pynq CNV-W1A1 style).
    """
    if abits == 1:
        return 1, -1.0, 2.0
    nt = (1 << abits) - 1
    if signed:
        return nt, -float(1 << (abits - 1)), 1.0
    return nt, 0.0, 1.0
