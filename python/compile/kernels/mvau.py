"""Layer-1 Pallas kernel: the FINN Matrix-Vector-Activation Unit (MVAU).

The MVAU is the compute hot-spot of a FINN-style dataflow accelerator: one
quantized matrix-vector product (the im2col'd convolution / fully-connected
layer) followed by a thresholding activation that folds batch-norm + quantized
activation into integer comparisons (the paper's "streamlining").

FINN folding is expressed directly in the Pallas grid:

  * grid axis 1 -- the *neuron fold* NF = C_out / PE (one tile of PE output
    channels per step);
  * grid axis 2 -- the *synapse fold* SF = S / SIMD (accumulation over
    SIMD-wide input tiles, innermost / sequential);
  * grid axis 0 -- pixel tiles (rows of the im2col matrix).

Each grid step stages exactly one (SIMD x PE) weight tile -- the same weight
read schedule the FINN weight streamer performs from BRAM, which is what the
paper's FCMP technique packs and overclocks.  On TPU this BlockSpec is the
HBM->VMEM schedule; here we run with ``interpret=True`` (CPU image: real TPU
lowering emits a Mosaic custom-call the CPU PJRT plugin cannot execute).

Thresholding uses the uniform-quantization linear form: with T[c, 0..NT-1] the
per-channel ascending thresholds, ``out = base + step * #{t : acc >= T[c,t]}``.
``NT = 0`` (empty threshold tensor) bypasses activation and emits the raw
accumulator (used by the final classifier layer).

All tensors are float32 *value-wise integers* (weights in {-1,+1} or
{-1,0,+1}, activations at their quantized integer levels): the MXU/ALU math is
exact for these magnitudes and f32 keeps the artifact runnable on any PJRT
backend.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mvau_kernel(x_ref, w_ref, t_ref, o_ref, *, nsf: int, base: float, step: float):
    """One (pixel-tile, neuron-fold, synapse-fold) grid step.

    x_ref : (BP, SIMD)   activation tile
    w_ref : (SIMD, PE)   weight tile (the streamer's per-cycle read)
    t_ref : (PE, NT)     per-channel thresholds (NT may be 0)
    o_ref : (BP, PE)     output tile; holds the running accumulator until the
                         last synapse-fold step, then the thresholded levels
    """
    sf = pl.program_id(2)

    @pl.when(sf == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)

    if t_ref is not None:

        @pl.when(sf == nsf - 1)
        def _activate():
            acc = o_ref[...]
            # count thresholds crossed: (BP, PE, NT) >= (PE, NT)
            crossed = (acc[:, :, None] >= t_ref[...][None, :, :]).astype(jnp.float32)
            o_ref[...] = base + step * jnp.sum(crossed, axis=2)


def _pick_tile(n: int, target: int) -> int:
    """Largest divisor of ``n`` that is <= ``target`` (folding must divide)."""
    t = min(n, target)
    while n % t != 0:
        t -= 1
    return t


@functools.partial(
    jax.jit, static_argnames=("pe", "simd", "base", "step", "pixel_tile")
)
def mvau(
    x: jax.Array,
    w: jax.Array,
    t: jax.Array,
    *,
    pe: int,
    simd: int,
    base: float = 0.0,
    step: float = 1.0,
    pixel_tile: int = 128,
) -> jax.Array:
    """Folded quantized matvec + thresholding (the FINN MVAU).

    Args:
      x: (P, S) im2col'd activations (P pixels, S = K*K*C_in synapses).
      w: (S, C_out) quantized weight matrix.
      t: (C_out, NT) ascending per-channel thresholds; NT = 0 bypasses
         activation and returns the raw accumulator.
      pe: output-channel parallelism (must divide C_out).
      simd: input parallelism (must divide S).
      base, step: uniform-quant level mapping ``out = base + step * count``.
      pixel_tile: im2col row tile size (clamped to a divisor of P).

    Returns:
      (P, C_out) float32 tensor of quantized activation levels (or raw
      accumulators when NT = 0).
    """
    p, s = x.shape
    s2, c_out = w.shape
    assert s == s2, f"synapse dim mismatch: {s} vs {s2}"
    assert t.shape[0] == c_out, f"threshold channels {t.shape[0]} != {c_out}"
    assert c_out % pe == 0, f"PE {pe} must divide C_out {c_out}"
    assert s % simd == 0, f"SIMD {simd} must divide S {s}"

    bp = _pick_tile(p, pixel_tile)
    nf = c_out // pe
    nsf = s // simd

    nt = t.shape[1]
    in_specs = [
        pl.BlockSpec((bp, simd), lambda i, j, k: (i, k)),
        pl.BlockSpec((simd, pe), lambda i, j, k: (k, j)),
    ]
    operands = [x, w]
    if nt > 0:
        in_specs.append(pl.BlockSpec((pe, nt), lambda i, j, k: (j, 0)))
        operands.append(t)
        kernel = functools.partial(
            _mvau_kernel, nsf=nsf, base=float(base), step=float(step)
        )
    else:
        # threshold bypass (raw accumulator out): no threshold operand at all,
        # since a zero-width BlockSpec is not representable.
        def kernel(x_ref, w_ref, o_ref):
            _mvau_kernel(
                x_ref, w_ref, None, o_ref, nsf=nsf, base=0.0, step=1.0
            )

    return pl.pallas_call(
        kernel,
        grid=(p // bp, nf, nsf),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bp, pe), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((p, c_out), jnp.float32),
        interpret=True,
    )(*operands)


def mvau_vmem_bits(pe: int, simd: int, bp: int, nt: int, wbits: int) -> int:
    """Estimated VMEM footprint (bits) of one grid step -- the TPU analogue of
    the per-MVAU BRAM budget (see DESIGN.md section Hardware-Adaptation)."""
    x_bits = bp * simd * 32
    w_bits = simd * pe * wbits
    t_bits = pe * nt * 32
    o_bits = bp * pe * 32
    return x_bits + w_bits + t_bits + o_bits
