"""AOT compile path: lower the L2 JAX models to HLO *text* artifacts.

Run once by ``make artifacts``; python never appears on the request path.
For every model variant this emits into ``artifacts/``:

  <name>.b<B>.hlo.txt      HLO text per batch size (the interchange format:
                           jax >= 0.5 serialized HloModuleProto uses 64-bit
                           instruction ids which xla_extension 0.5.1 rejects;
                           the text parser reassigns ids and round-trips)
  weights/<name>/NNN.bin   float32 little-endian parameter tensors, in the
                           exact argument order of the lowered function
  golden/<name>.in.bin     one deterministic input batch and the jax-computed
  golden/<name>.out.bin    output for it -- the rust runtime must match it
                           bit-for-bit (integer-valued f32 math)
  <name>.manifest          plain-text manifest the rust runtime parses:
                           hlo/batch/input/param/output/golden lines

Usage: python -m compile.aot --out ../artifacts [--models cnv_w1a1,...]
"""

from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M

# Executable model registry: name -> (builder of (fn, layers, interleaved,
# input_hw)).  rn50 full-size shapes are handled analytically on the rust
# side; the lite variant proves the three-layer stack end to end.
MODELS = {
    "cnv_w1a1": dict(kind="cnv", wbits=1, abits=1, image=32),
    "cnv_w2a2": dict(kind="cnv", wbits=2, abits=2, image=32),
    "rn50_lite_w1a2": dict(kind="rn50", wbits=1, width_scale=0.25, image=32),
}

BATCHES = {"cnv_w1a1": (1, 4), "cnv_w2a2": (1,), "rn50_lite_w1a2": (1,)}


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def build(name: str):
    """Return (forward_fn(x, *params), layers, params list) for a model.

    Zero-element parameters (empty threshold tensors of bypass layers) are
    excluded from the lowered signature — jax prunes unused arguments and a
    0-element literal is not expressible on the rust side anyway; the
    forward wrapper re-inserts empty placeholders at the right positions.
    """
    cfg = MODELS[name]
    if cfg["kind"] == "cnv":
        layers = M.cnv_layers(cfg["wbits"], cfg["abits"])
        all_params = M.init_params(layers)

        def full_fn(x, ps):
            return (M.cnv_forward(x, ps, cfg["wbits"], cfg["abits"], full_fold=True),)

    else:
        layers = M.rn50_param_layers(cfg["wbits"], cfg["width_scale"])
        all_params = M.init_params(layers, interleaved=True)

        def full_fn(x, ps):
            return (M.rn50_forward(x, ps, cfg["wbits"], cfg["width_scale"], full_fold=True),)

    keep = [i for i, p in enumerate(all_params) if p.size > 0]
    shapes = [p.shape for p in all_params]

    def fn(x, *nz):
        it = iter(nz)
        full = [
            next(it) if i in set(keep) else jnp.zeros(shapes[i], jnp.float32)
            for i in range(len(all_params))
        ]
        return full_fn(x, full)

    params = [all_params[i] for i in keep]
    return fn, layers, params


def golden_input(name: str, batch: int) -> np.ndarray:
    cfg = MODELS[name]
    rng = np.random.RandomState(hash(name) % (2**31 - 1))
    img = cfg["image"]
    # 8-bit input images, as consumed by the first (8-bit-weight) layer
    return rng.randint(0, 256, (batch, img, img, 3)).astype(np.float32)


def emit(name: str, out_dir: str) -> None:
    fn, _layers, params = build(name)
    wdir = os.path.join(out_dir, "weights", name)
    gdir = os.path.join(out_dir, "golden")
    os.makedirs(wdir, exist_ok=True)
    os.makedirs(gdir, exist_ok=True)

    manifest = [f"model {name}"]
    for i, p in enumerate(params):
        fname = f"{i:03d}.bin"
        p.astype("<f4").tofile(os.path.join(wdir, fname))
        dims = " ".join(str(d) for d in p.shape)
        manifest.append(f"param weights/{name}/{fname} {dims}")

    jparams = [jnp.array(p) for p in params]
    for batch in BATCHES[name]:
        spec = jax.ShapeDtypeStruct(golden_input(name, batch).shape, jnp.float32)
        pspecs = [jax.ShapeDtypeStruct(p.shape, jnp.float32) for p in params]
        lowered = jax.jit(fn).lower(spec, *pspecs)
        hlo = to_hlo_text(lowered)
        hlo_name = f"{name}.b{batch}.hlo.txt"
        with open(os.path.join(out_dir, hlo_name), "w") as f:
            f.write(hlo)
        manifest.append(f"hlo {batch} {hlo_name}")
        print(f"  {hlo_name}: {len(hlo) / 1e6:.1f} MB text")

    # golden I/O at the smallest batch
    b0 = BATCHES[name][0]
    x = golden_input(name, b0)
    y = np.asarray(fn(jnp.array(x), *jparams)[0])
    x.astype("<f4").tofile(os.path.join(gdir, f"{name}.in.bin"))
    y.astype("<f4").tofile(os.path.join(gdir, f"{name}.out.bin"))
    manifest.append(
        f"input {b0} " + " ".join(str(d) for d in x.shape[1:])
    )
    manifest.append(f"output {b0} " + " ".join(str(d) for d in y.shape[1:]))
    manifest.append(f"golden golden/{name}.in.bin golden/{name}.out.bin")

    with open(os.path.join(out_dir, f"{name}.manifest"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    print(f"  {name}: {len(params)} params, golden out shape {y.shape}")


def emit_unit_mvau(out_dir: str) -> None:
    """A single small MVAU as its own artifact -- the runtime's micro-test
    (kernel-level golden check without a full network around it)."""
    from .kernels.mvau import mvau
    from .kernels.ref import threshold_params

    p, s, c, pe, simd, abits = 8, 36, 16, 4, 6, 2
    nt, base, step = threshold_params(abits)
    rng = np.random.RandomState(77)
    w = rng.choice([-1.0, 1.0], (s, c)).astype(np.float32)
    t = np.sort(np.round(rng.uniform(-6, 6, (c, nt))), axis=1).astype(np.float32)
    x = rng.randint(-2, 2, (p, s)).astype(np.float32)

    def fn(xx, ww, tt):
        return (mvau(xx, ww, tt, pe=pe, simd=simd, base=base, step=step),)

    specs = [jax.ShapeDtypeStruct(a.shape, jnp.float32) for a in (x, w, t)]
    hlo = to_hlo_text(jax.jit(fn).lower(*specs))
    with open(os.path.join(out_dir, "mvau_unit.hlo.txt"), "w") as f:
        f.write(hlo)
    y = np.asarray(fn(jnp.array(x), jnp.array(w), jnp.array(t))[0])
    gdir = os.path.join(out_dir, "golden")
    os.makedirs(gdir, exist_ok=True)
    for arr, nm in ((x, "x"), (w, "w"), (t, "t"), (y, "y")):
        arr.astype("<f4").tofile(os.path.join(gdir, f"mvau_unit.{nm}.bin"))
    with open(os.path.join(out_dir, "mvau_unit.manifest"), "w") as f:
        f.write(
            "model mvau_unit\n"
            f"hlo 1 mvau_unit.hlo.txt\n"
            f"arg golden/mvau_unit.x.bin {p} {s}\n"
            f"arg golden/mvau_unit.w.bin {s} {c}\n"
            f"arg golden/mvau_unit.t.bin {c} {nt}\n"
            f"expect golden/mvau_unit.y.bin {p} {c}\n"
        )
    print(f"  mvau_unit: {len(hlo) / 1e3:.0f} KB text")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--models", default=",".join(MODELS))
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    emit_unit_mvau(args.out)
    for name in args.models.split(","):
        if name:
            print(f"lowering {name} ...")
            emit(name, args.out)


if __name__ == "__main__":
    main()
