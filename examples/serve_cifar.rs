//! End-to-end driver (DESIGN.md E2E): serve batched CIFAR-10 inference
//! requests through the full three-layer stack —
//!
//!   rust coordinator (router → dynamic batcher → worker)
//!     → PJRT runtime executing the AOT HLO artifact
//!       → which embeds the Pallas MVAU kernels of the quantized CNV
//!
//! and report throughput + latency percentiles. Requires `make artifacts`.
//! The run is recorded in EXPERIMENTS.md §E2E.
//!
//! Run: `cargo run --release --example serve_cifar -- [requests] [rate]`

use fcmp::coordinator::{BatcherConfig, Metrics, Server, ServerConfig};
use fcmp::runtime::Engine;
use fcmp::util::rng::Rng;
use std::path::Path;
use std::time::Duration;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let n: u64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(96);
    let rate: f64 = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(40.0);
    let arts = Path::new("artifacts");

    // verify numerics against the python golden output before serving
    let probe = Engine::load(arts, "cnv_w1a1")?;
    probe.check_golden()?;
    println!(
        "engine: cnv_w1a1 on {} — golden check OK, batch variants {:?}",
        probe.platform(),
        probe.batch_sizes()
    );
    let per = probe.manifest.input_elements_per_sample() as usize;
    drop(probe);

    let cfg = ServerConfig {
        batcher: BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(3) },
        queue_depth: 256,
    };
    let mut srv = Server::start(
        move || Engine::load(Path::new("artifacts"), "cnv_w1a1").expect("engine"),
        cfg,
    );

    // open-loop arrival process at `rate` req/s (synthetic CIFAR-10 images)
    let mut rng = Rng::new(2020);
    let mut metrics = Metrics::new();
    metrics.start();
    let t0 = std::time::Instant::now();
    let (mut submitted, mut received) = (0u64, 0u64);
    let mut argmax_histogram = [0usize; 16];
    while received < n {
        if submitted < n && t0.elapsed().as_secs_f64() >= submitted as f64 / rate {
            let img: Vec<f32> = (0..per).map(|_| rng.below(256) as f32).collect();
            srv.submit_blocking(submitted, img)?;
            submitted += 1;
            continue;
        }
        match srv.next_completion() {
            Some(c) => {
                let (mut best, mut arg) = (f32::NEG_INFINITY, 0);
                for (k, &v) in c.output.iter().enumerate().take(10) {
                    if v > best {
                        best = v;
                        arg = k;
                    }
                }
                argmax_histogram[arg] += 1;
                metrics.record(c.latency, c.batch_size);
                received += 1;
            }
            None => break,
        }
    }
    srv.shutdown();

    let s = metrics.summary();
    println!("E2E serve: {s}");
    println!("class histogram (synthetic inputs): {argmax_histogram:?}");
    assert_eq!(s.requests as u64, n, "all requests must complete");
    println!("serve_cifar OK");
    Ok(())
}
